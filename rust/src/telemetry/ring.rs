//! Bounded lock-free SPSC ring for telemetry hand-off.
//!
//! Each pipeline worker owns the producer half of one ring; a single
//! aggregator thread owns the consumer half and drains spans into the
//! [`Collector`](super::Collector). The design is the classic
//! single-producer/single-consumer circular buffer:
//!
//! - capacity is a power of two, so `index & mask` replaces `%`;
//! - `head` (consumer) and `tail` (producer) are monotonically increasing
//!   counters on their own cache lines, each written by exactly one side;
//! - a push writes the slot *then* publishes it with a `Release` store of
//!   `tail` (reserve/commit); a pop observes `tail` with `Acquire`, so
//!   slot contents are visible before the index that covers them;
//! - when the ring is full the producer **drops the value and counts it**
//!   — backpressure must never block the pipeline-under-test, and an
//!   explicit drop counter keeps the measurement honest (the drain loop
//!   reports drops instead of silently undercounting).
//!
//! Each side also keeps a *cached* copy of the other side's index and only
//! re-reads the shared atomic when the cache says full/empty, which keeps
//! steady-state pushes and pops free of cross-core traffic.
//!
//! This module contains the repo's only `unsafe` code: slot storage is
//! `UnsafeCell<MaybeUninit<T>>`, sound because the head/tail protocol
//! gives every slot exactly one writer at a time (the SAFETY comments on
//! each block spell out the invariant they rely on).

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Pad to a cache line so producer and consumer indices never false-share.
#[repr(align(64))]
#[derive(Debug, Default)]
struct CachePadded<T>(T);

#[derive(Debug)]
struct Slot<T>(UnsafeCell<MaybeUninit<T>>);

/// State shared by the two halves. Private — only [`ring`] constructs it.
#[derive(Debug)]
struct Shared<T> {
    buf: Box<[Slot<T>]>,
    mask: usize,
    /// Next index the consumer will read. Written only by the consumer.
    head: CachePadded<AtomicUsize>,
    /// Next index the producer will write. Written only by the producer.
    tail: CachePadded<AtomicUsize>,
    /// Values rejected because the ring was full.
    dropped: CachePadded<AtomicU64>,
}

// SAFETY: the buffer is only touched through the head/tail protocol —
// every slot in `[head, tail)` is initialized and owned by the consumer,
// every slot outside it is vacant and owned by the producer — so sharing
// `Shared<T>` across the two threads moves `T` values between threads
// (requires `T: Send`) but never aliases a slot mutably.
unsafe impl<T: Send> Send for Shared<T> {}
unsafe impl<T: Send> Sync for Shared<T> {}

impl<T> Drop for Shared<T> {
    fn drop(&mut self) {
        // `&mut self` proves both halves are gone; drop the undrained
        // values in `[head, tail)`.
        let head = *self.head.0.get_mut();
        let tail = *self.tail.0.get_mut();
        let mut i = head;
        while i != tail {
            // SAFETY: slots in [head, tail) were written by a committed
            // push and never popped, so they hold initialized values.
            unsafe { (*self.buf[i & self.mask].0.get()).assume_init_drop() };
            i = i.wrapping_add(1);
        }
    }
}

/// Producer half: owned by exactly one worker thread (deliberately not
/// `Clone` — a second producer would break the single-writer invariant).
#[derive(Debug)]
pub struct RingProducer<T> {
    shared: Arc<Shared<T>>,
    /// Local copy of `tail` (this side is its only writer).
    tail: usize,
    /// Last observed `head`; refreshed only when the ring looks full.
    head_cache: usize,
}

/// Consumer half: owned by the single aggregator thread (not `Clone`).
#[derive(Debug)]
pub struct RingConsumer<T> {
    shared: Arc<Shared<T>>,
    /// Local copy of `head` (this side is its only writer).
    head: usize,
    /// Last observed `tail`; refreshed only when the ring looks empty.
    tail_cache: usize,
}

/// Create a ring with at least `capacity` slots (rounded up to the next
/// power of two, minimum 2). Returns the producer and consumer halves.
pub fn ring<T>(capacity: usize) -> (RingProducer<T>, RingConsumer<T>) {
    let cap = capacity.next_power_of_two().max(2);
    let buf: Box<[Slot<T>]> = (0..cap)
        .map(|_| Slot(UnsafeCell::new(MaybeUninit::uninit())))
        .collect();
    let shared = Arc::new(Shared {
        buf,
        mask: cap - 1,
        head: CachePadded(AtomicUsize::new(0)),
        tail: CachePadded(AtomicUsize::new(0)),
        dropped: CachePadded(AtomicU64::new(0)),
    });
    (
        RingProducer {
            shared: shared.clone(),
            tail: 0,
            head_cache: 0,
        },
        RingConsumer {
            shared,
            head: 0,
            tail_cache: 0,
        },
    )
}

impl<T> RingProducer<T> {
    /// Slot count (power of two).
    pub fn capacity(&self) -> usize {
        self.shared.mask + 1
    }

    /// Push a value without ever blocking. Returns `false` — and bumps the
    /// drop counter — if the ring is full; the value is discarded so the
    /// producing worker's timing is never perturbed by a slow aggregator.
    pub fn push(&mut self, value: T) -> bool {
        let cap = self.shared.mask + 1;
        if self.tail.wrapping_sub(self.head_cache) >= cap {
            // looked full through the cache: refresh from the consumer
            self.head_cache = self.shared.head.0.load(Ordering::Acquire);
            if self.tail.wrapping_sub(self.head_cache) >= cap {
                self.shared.dropped.0.fetch_add(1, Ordering::Relaxed);
                return false;
            }
        }
        // SAFETY: `tail - head <= mask` here, so slot `tail & mask` is
        // outside `[head, tail)` — vacant and owned by this producer. The
        // Release store below publishes the write before the new tail.
        unsafe { (*self.shared.buf[self.tail & self.shared.mask].0.get()).write(value) };
        self.tail = self.tail.wrapping_add(1);
        self.shared.tail.0.store(self.tail, Ordering::Release);
        true
    }

    /// Values dropped on overflow since the ring was created.
    pub fn dropped(&self) -> u64 {
        self.shared.dropped.0.load(Ordering::Relaxed)
    }
}

impl<T> RingConsumer<T> {
    /// Slot count (power of two).
    pub fn capacity(&self) -> usize {
        self.shared.mask + 1
    }

    /// Pop the oldest value, or `None` if the ring is empty.
    pub fn pop(&mut self) -> Option<T> {
        if self.head == self.tail_cache {
            // looked empty through the cache: refresh from the producer.
            // Acquire pairs with the producer's Release tail store, making
            // the slot writes below visible.
            self.tail_cache = self.shared.tail.0.load(Ordering::Acquire);
            if self.head == self.tail_cache {
                return None;
            }
        }
        // SAFETY: `head < tail`, so slot `head & mask` holds a value a
        // committed push published; this is the only consumer, so the
        // value is read exactly once before the slot is handed back via
        // the Release head store.
        let value =
            unsafe { (*self.shared.buf[self.head & self.shared.mask].0.get()).assume_init_read() };
        self.head = self.head.wrapping_add(1);
        self.shared.head.0.store(self.head, Ordering::Release);
        Some(value)
    }

    /// Drain everything currently visible into `out`; returns how many
    /// values were moved.
    pub fn drain_into(&mut self, out: &mut Vec<T>) -> usize {
        let mut n = 0;
        while let Some(v) = self.pop() {
            out.push(v);
            n += 1;
        }
        n
    }

    /// Values the producer dropped on overflow since the ring was created.
    pub fn dropped(&self) -> u64 {
        self.shared.dropped.0.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        let (p, _c) = ring::<u64>(100);
        assert_eq!(p.capacity(), 128);
        let (p, _c) = ring::<u64>(0);
        assert_eq!(p.capacity(), 2);
    }

    #[test]
    fn push_pop_fifo() {
        let (mut p, mut c) = ring(8);
        for i in 0..5 {
            assert!(p.push(i));
        }
        for i in 0..5 {
            assert_eq!(c.pop(), Some(i));
        }
        assert_eq!(c.pop(), None);
    }

    #[test]
    fn overflow_drops_and_counts() {
        let (mut p, mut c) = ring(4);
        for i in 0..4 {
            assert!(p.push(i));
        }
        assert!(!p.push(99));
        assert!(!p.push(100));
        assert_eq!(p.dropped(), 2);
        assert_eq!(c.dropped(), 2);
        // the four committed values survive in order; the dropped ones
        // never appear
        let mut out = Vec::new();
        assert_eq!(c.drain_into(&mut out), 4);
        assert_eq!(out, vec![0, 1, 2, 3]);
    }

    #[test]
    fn wraps_around_many_times() {
        let (mut p, mut c) = ring(4);
        for i in 0..10_000u64 {
            assert!(p.push(i));
            assert_eq!(c.pop(), Some(i));
        }
    }

    #[test]
    fn drop_releases_undrained_values() {
        let val = Arc::new(());
        let (mut p, c) = ring(8);
        for _ in 0..5 {
            assert!(p.push(val.clone()));
        }
        assert_eq!(Arc::strong_count(&val), 6);
        drop(p);
        drop(c);
        assert_eq!(Arc::strong_count(&val), 1);
    }

    #[test]
    fn cross_thread_spsc_no_loss() {
        let (mut p, mut c) = ring(1 << 10);
        const N: u64 = 200_000;
        let producer = std::thread::spawn(move || {
            let mut refused = 0u64;
            for i in 0..N {
                // spin until space: this test wants lossless transfer.
                // Each refused attempt still bumps the drop counter —
                // the retry compensates the value, not the count.
                while !p.push(i) {
                    refused += 1;
                    std::hint::spin_loop();
                }
            }
            (p, refused)
        });
        let mut next = 0u64;
        while next < N {
            match c.pop() {
                Some(v) => {
                    assert_eq!(v, next, "out-of-order or torn value");
                    next += 1;
                }
                None => std::hint::spin_loop(),
            }
        }
        let (p, refused) = producer.join().unwrap();
        assert_eq!(p.dropped(), refused, "every refusal is counted exactly once");
        assert_eq!(c.pop(), None);
    }
}

//! Seqlock-published snapshot cells for multi-word counters.
//!
//! The real-mode cost meter needs to expose a *consistent* multi-word
//! snapshot (cpu seconds, memory seconds, tick count, …) to readers while
//! a pipeline worker updates it on every tick. A mutex would put the
//! harness back on the hot path — the exact perturbation §V.B of the
//! paper tells the measurement layer to avoid. A seqlock keeps the writer
//! wait-free: it bumps a version counter to an odd value, stores the
//! payload words, then bumps the version to the next even value. Readers
//! retry until they observe the *same even version* before and after
//! loading the words, which proves no write overlapped the read.
//!
//! The payload travels as `u64` words (floats via [`f64::to_bits`]), so
//! the cell is plain safe Rust over atomics — no `unsafe`, no torn loads
//! at the word level, and the version protocol rules out torn *snapshots*
//! across words. Writes are expected to come from one thread at a time
//! (the meter is `&mut`-owned by its worker); the writer nonetheless
//! claims the cell with a compare-exchange so a misuse from two threads
//! degrades to one of them spinning, never to a torn snapshot.

use std::sync::atomic::{fence, AtomicU64, Ordering};

/// An `N`-word seqlock cell. Writers publish all `N` words atomically
/// with respect to readers; readers never block the writer.
#[derive(Debug)]
pub struct Seqlock<const N: usize> {
    /// Even = stable, odd = write in progress.
    version: AtomicU64,
    words: [AtomicU64; N],
}

impl<const N: usize> Default for Seqlock<N> {
    fn default() -> Self {
        Self::new()
    }
}

impl<const N: usize> Seqlock<N> {
    /// A cell whose words all start at zero (version 0 = stable).
    pub fn new() -> Self {
        Seqlock {
            version: AtomicU64::new(0),
            words: std::array::from_fn(|_| AtomicU64::new(0)),
        }
    }

    /// Publish a new snapshot. Wait-free for the single intended writer;
    /// if two writers race (a misuse), the loser spins until the cell is
    /// stable again rather than corrupting it.
    pub fn write(&self, words: &[u64; N]) {
        let mut v = self.version.load(Ordering::Relaxed);
        loop {
            // only claim a stable (even) version; odd means another write
            // is mid-flight
            if v % 2 == 0 {
                match self.version.compare_exchange_weak(
                    v,
                    v + 1,
                    Ordering::Acquire,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(actual) => v = actual,
                }
            } else {
                std::hint::spin_loop();
                v = self.version.load(Ordering::Relaxed);
            }
        }
        for (slot, w) in self.words.iter().zip(words) {
            slot.store(*w, Ordering::Release);
        }
        // v+2 is even again; Release orders the word stores before it
        self.version.store(v + 2, Ordering::Release);
    }

    /// Read a consistent snapshot. Lock-free: retries while a write is in
    /// flight, which on the intended single-writer cell is a few loads.
    pub fn read(&self) -> [u64; N] {
        loop {
            let v1 = self.version.load(Ordering::Acquire);
            if v1 % 2 != 0 {
                std::hint::spin_loop();
                continue;
            }
            let mut out = [0u64; N];
            for (o, w) in out.iter_mut().zip(&self.words) {
                *o = w.load(Ordering::Acquire);
            }
            // the fence orders the word loads before the version re-check:
            // if the version still matches, no writer touched the cell
            // while we were reading
            fence(Ordering::Acquire);
            if self.version.load(Ordering::Relaxed) == v1 {
                return out;
            }
            std::hint::spin_loop();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicBool;
    use std::sync::Arc;

    #[test]
    fn fresh_cell_reads_zero() {
        let cell: Seqlock<3> = Seqlock::new();
        assert_eq!(cell.read(), [0, 0, 0]);
    }

    #[test]
    fn write_then_read_round_trips() {
        let cell: Seqlock<2> = Seqlock::new();
        cell.write(&[7, 9]);
        assert_eq!(cell.read(), [7, 9]);
        cell.write(&[1, 2]);
        assert_eq!(cell.read(), [1, 2]);
    }

    #[test]
    fn f64_bits_round_trip() {
        let cell: Seqlock<1> = Seqlock::new();
        cell.write(&[1.25f64.to_bits()]);
        assert_eq!(f64::from_bits(cell.read()[0]), 1.25);
    }

    #[test]
    fn reader_never_sees_torn_snapshot() {
        // writer publishes [k, 2k]; any snapshot where the second word is
        // not exactly twice the first is torn
        let cell: Arc<Seqlock<2>> = Arc::new(Seqlock::new());
        let stop = Arc::new(AtomicBool::new(false));
        let w = {
            let cell = cell.clone();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let mut k = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    k += 1;
                    cell.write(&[k, 2 * k]);
                }
            })
        };
        let readers: Vec<_> = (0..3)
            .map(|_| {
                let cell = cell.clone();
                std::thread::spawn(move || {
                    for _ in 0..200_000 {
                        let [a, b] = cell.read();
                        assert_eq!(b, 2 * a, "torn snapshot: [{a}, {b}]");
                    }
                })
            })
            .collect();
        for r in readers {
            r.join().unwrap();
        }
        stop.store(true, Ordering::Relaxed);
        w.join().unwrap();
    }
}

//! Spans and the span→metric collector.
//!
//! A [`Span`] is the unit of white-box instrumentation the paper asks
//! pipeline engineers to add (§V.B): stage name, start time, duration, and
//! payload counters. Stages push spans into a [`SpanSink`]; the
//! [`Collector`] converts each span into TSDB samples:
//!
//! - `stage_records{stage=..}`   — records processed by the span
//! - `stage_bytes{stage=..}`     — bytes processed
//! - `stage_latency_s{stage=..}` — span duration (seconds)
//! - `stage_errors{stage=..}`    — 1 per failed span
//!
//! Samples are timestamped at span *end* (start + duration), which is when
//! the work became externally visible.

use std::sync::{Arc, Mutex};

use super::tsdb::{SeriesHandle, Tsdb};

/// One instrumented unit of stage work.
#[derive(Debug, Clone)]
pub struct Span {
    /// Trace correlation id — constant across stages for one input record.
    pub trace_id: u64,
    /// Stage name, e.g. `"unzipper_phase"`.
    pub stage: &'static str,
    /// Virtual start time, seconds.
    pub start_s: f64,
    /// Span duration, virtual seconds.
    pub duration_s: f64,
    /// Records handled in this span (a stage may split/join records).
    pub records: u64,
    /// Payload bytes handled.
    pub bytes: u64,
    /// Whether the work succeeded.
    pub ok: bool,
}

impl Span {
    /// Virtual time the span's work became externally visible.
    pub fn end_s(&self) -> f64 {
        self.start_s + self.duration_s
    }
}

/// Shared buffer the pipeline's stages push spans into. The experiment
/// controller drains it through a [`Collector`].
#[derive(Debug, Clone, Default)]
pub struct SpanSink {
    spans: Arc<Mutex<Vec<Span>>>,
}

impl SpanSink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer one span (called from stage threads).
    pub fn push(&self, span: Span) {
        self.spans.lock().unwrap().push(span);
    }

    /// Remove and return all buffered spans.
    pub fn drain(&self) -> Vec<Span> {
        std::mem::take(&mut *self.spans.lock().unwrap())
    }

    /// Number of buffered spans.
    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Converts spans into TSDB metric samples, caching series handles per
/// stage (ingest is hot during experiments).
pub struct Collector {
    tsdb: Tsdb,
    by_stage: Mutex<std::collections::HashMap<&'static str, StageSeries>>,
}

struct StageSeries {
    records: SeriesHandle,
    bytes: SeriesHandle,
    latency: SeriesHandle,
    errors: SeriesHandle,
}

impl Collector {
    /// Collector writing into `tsdb`.
    pub fn new(tsdb: Tsdb) -> Self {
        Collector {
            tsdb,
            by_stage: Mutex::new(Default::default()),
        }
    }

    /// The TSDB this collector writes into.
    pub fn tsdb(&self) -> &Tsdb {
        &self.tsdb
    }

    /// Convert one span into metric samples.
    pub fn record(&self, span: &Span) {
        let mut map = self.by_stage.lock().unwrap();
        let series = map.entry(span.stage).or_insert_with(|| StageSeries {
            records: self.tsdb.series("stage_records", &[("stage", span.stage)]),
            bytes: self.tsdb.series("stage_bytes", &[("stage", span.stage)]),
            latency: self
                .tsdb
                .series("stage_latency_s", &[("stage", span.stage)]),
            errors: self.tsdb.series("stage_errors", &[("stage", span.stage)]),
        });
        let t = span.end_s();
        series.records.push(t, span.records as f64);
        series.bytes.push(t, span.bytes as f64);
        series.latency.push(t, span.duration_s);
        if !span.ok {
            series.errors.push(t, 1.0);
        }
    }

    /// Drain a sink into the TSDB; returns the number of spans collected.
    pub fn collect_from(&self, sink: &SpanSink) -> usize {
        let spans = sink.drain();
        for s in &spans {
            self.record(s);
        }
        spans.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(stage: &'static str, start: f64, dur: f64, recs: u64, ok: bool) -> Span {
        Span {
            trace_id: 1,
            stage,
            start_s: start,
            duration_s: dur,
            records: recs,
            bytes: recs * 100,
            ok,
        }
    }

    #[test]
    fn span_end_time() {
        assert_eq!(span("s", 2.0, 0.5, 1, true).end_s(), 2.5);
    }

    #[test]
    fn sink_push_drain() {
        let sink = SpanSink::new();
        sink.push(span("a", 0.0, 1.0, 1, true));
        sink.push(span("b", 0.0, 1.0, 1, true));
        assert_eq!(sink.len(), 2);
        let drained = sink.drain();
        assert_eq!(drained.len(), 2);
        assert!(sink.is_empty());
    }

    #[test]
    fn collector_emits_per_stage_metrics() {
        let db = Tsdb::new();
        let c = Collector::new(db.clone());
        c.record(&span("etl", 1.0, 0.25, 5, true));
        let recs = db.samples("stage_records", &[("stage", "etl")]);
        assert_eq!(recs, vec![(1.25, 5.0)]);
        let lat = db.samples("stage_latency_s", &[("stage", "etl")]);
        assert_eq!(lat, vec![(1.25, 0.25)]);
        assert!(db.samples("stage_errors", &[("stage", "etl")]).is_empty());
    }

    #[test]
    fn collector_counts_errors() {
        let db = Tsdb::new();
        let c = Collector::new(db.clone());
        c.record(&span("v2x", 0.0, 0.1, 1, false));
        c.record(&span("v2x", 0.2, 0.1, 1, false));
        assert_eq!(db.sum_range("stage_errors", &[("stage", "v2x")], 0.0, 1.0), 2.0);
    }

    #[test]
    fn collect_from_drains_sink() {
        let db = Tsdb::new();
        let c = Collector::new(db.clone());
        let sink = SpanSink::new();
        for i in 0..10 {
            sink.push(span("u", i as f64, 0.5, 2, true));
        }
        assert_eq!(c.collect_from(&sink), 10);
        assert!(sink.is_empty());
        assert_eq!(db.sum_range("stage_records", &[("stage", "u")], 0.0, 100.0), 20.0);
    }

    #[test]
    fn stages_do_not_mix() {
        let db = Tsdb::new();
        let c = Collector::new(db.clone());
        c.record(&span("a", 0.0, 0.1, 1, true));
        c.record(&span("b", 0.0, 0.2, 9, true));
        assert_eq!(db.sum_range("stage_records", &[("stage", "a")], 0.0, 10.0), 1.0);
        assert_eq!(db.sum_range("stage_records", &[("stage", "b")], 0.0, 10.0), 9.0);
    }
}

//! Spans and the span→metric collector.
//!
//! A [`Span`] is the unit of white-box instrumentation the paper asks
//! pipeline engineers to add (§V.B): stage name, start time, duration, and
//! payload counters. Stages push spans into a [`SpanSink`] (or, on the
//! real-mode hot path, a lock-free [`ring`](super::ring)); the
//! [`Collector`] converts each span into TSDB samples:
//!
//! - `stage_records{stage=..}`   — records processed by the span
//! - `stage_bytes{stage=..}`     — bytes processed
//! - `stage_latency_s{stage=..}` — span duration (seconds)
//! - `stage_errors{stage=..}`    — 1 per failed span
//! - `stage_cum_latency_s{stage=..,pipeline=..}` — ingest-to-stage-exit
//!   latency, derived from [`Span::ingest_s`] when the collector was built
//!   with [`Collector::with_pipeline`]
//!
//! Samples are timestamped at span *end* (start + duration), which is when
//! the work became externally visible.

use std::sync::{Arc, Mutex};

use super::ring::RingConsumer;
use super::tsdb::{SeriesHandle, Tsdb};

/// One instrumented unit of stage work.
#[derive(Debug, Clone)]
pub struct Span {
    /// Trace correlation id — constant across stages for one input record.
    pub trace_id: u64,
    /// Stage name, e.g. `"unzipper_phase"`.
    pub stage: &'static str,
    /// Virtual start time, seconds.
    pub start_s: f64,
    /// Span duration, virtual seconds.
    pub duration_s: f64,
    /// Virtual time the traced payload entered the *pipeline* (not this
    /// stage) — the anchor for cumulative latency. `NaN` when unknown.
    pub ingest_s: f64,
    /// Records handled in this span (a stage may split/join records).
    pub records: u64,
    /// Payload bytes handled.
    pub bytes: u64,
    /// Whether the work succeeded.
    pub ok: bool,
}

impl Span {
    /// Virtual time the span's work became externally visible.
    pub fn end_s(&self) -> f64 {
        self.start_s + self.duration_s
    }

    /// Ingest-to-stage-exit latency, if the ingest time is known.
    pub fn cum_latency_s(&self) -> Option<f64> {
        let lat = self.end_s() - self.ingest_s;
        lat.is_finite().then_some(lat)
    }
}

/// Shared buffer the pipeline's stages push spans into. The experiment
/// controller drains it through a [`Collector`].
///
/// This is the *synchronous* hand-off (sim mode, tests, campaign cells):
/// pushes take a mutex. The real-mode hot path uses per-worker
/// [`ring`](super::ring)s instead, so measurement never blocks the
/// pipeline-under-test.
#[derive(Debug, Clone, Default)]
pub struct SpanSink {
    spans: Arc<Mutex<Vec<Span>>>,
}

impl SpanSink {
    /// Empty sink.
    pub fn new() -> Self {
        Self::default()
    }

    /// Buffer one span (called from stage threads).
    pub fn push(&self, span: Span) {
        self.spans.lock().unwrap().push(span);
    }

    /// Remove and return all buffered spans.
    pub fn drain(&self) -> Vec<Span> {
        std::mem::take(&mut *self.spans.lock().unwrap())
    }

    /// Number of buffered spans.
    pub fn len(&self) -> usize {
        self.spans.lock().unwrap().len()
    }

    /// Whether the buffer is empty.
    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Converts spans into TSDB metric samples, caching series handles per
/// stage (ingest is hot during experiments).
pub struct Collector {
    tsdb: Tsdb,
    /// When set, spans with a known ingest time also produce
    /// `stage_cum_latency_s{stage, pipeline}` samples.
    pipeline: Option<String>,
    by_stage: Mutex<std::collections::HashMap<&'static str, StageSeries>>,
}

struct StageSeries {
    records: SeriesHandle,
    bytes: SeriesHandle,
    latency: SeriesHandle,
    errors: SeriesHandle,
    cum: Option<SeriesHandle>,
}

impl StageSeries {
    fn new(tsdb: &Tsdb, pipeline: Option<&str>, stage: &'static str) -> Self {
        StageSeries {
            records: tsdb.series("stage_records", &[("stage", stage)]),
            bytes: tsdb.series("stage_bytes", &[("stage", stage)]),
            latency: tsdb.series("stage_latency_s", &[("stage", stage)]),
            errors: tsdb.series("stage_errors", &[("stage", stage)]),
            cum: pipeline.map(|p| {
                tsdb.series("stage_cum_latency_s", &[("stage", stage), ("pipeline", p)])
            }),
        }
    }

    fn record(&self, span: &Span) {
        let t = span.end_s();
        self.records.push(t, span.records as f64);
        self.bytes.push(t, span.bytes as f64);
        self.latency.push(t, span.duration_s);
        if !span.ok {
            self.errors.push(t, 1.0);
        }
        if let (Some(cum), Some(lat)) = (&self.cum, span.cum_latency_s()) {
            cum.push(t, lat);
        }
    }
}

impl Collector {
    /// Collector writing into `tsdb`.
    pub fn new(tsdb: Tsdb) -> Self {
        Collector {
            tsdb,
            pipeline: None,
            by_stage: Mutex::new(Default::default()),
        }
    }

    /// Collector that additionally derives per-stage cumulative latency
    /// (`stage_cum_latency_s{stage, pipeline}`) from [`Span::ingest_s`].
    pub fn with_pipeline(tsdb: Tsdb, pipeline: &str) -> Self {
        Collector {
            tsdb,
            pipeline: Some(pipeline.to_string()),
            by_stage: Mutex::new(Default::default()),
        }
    }

    /// The TSDB this collector writes into.
    pub fn tsdb(&self) -> &Tsdb {
        &self.tsdb
    }

    /// Convert one span into metric samples.
    pub fn record(&self, span: &Span) {
        let mut map = self.by_stage.lock().unwrap();
        let series = map
            .entry(span.stage)
            .or_insert_with(|| StageSeries::new(&self.tsdb, self.pipeline.as_deref(), span.stage));
        series.record(span);
    }

    /// Convert a batch of spans with a single `by_stage` access — `&mut`
    /// proves exclusivity, so the aggregator's drain loop pays no lock at
    /// all instead of one per span.
    pub fn record_all(&mut self, spans: &[Span]) {
        let Collector {
            tsdb,
            pipeline,
            by_stage,
        } = self;
        let map = by_stage.get_mut().unwrap();
        for span in spans {
            let series = map
                .entry(span.stage)
                .or_insert_with(|| StageSeries::new(tsdb, pipeline.as_deref(), span.stage));
            series.record(span);
        }
    }

    /// Drain a sink into the TSDB; returns the number of spans collected.
    pub fn collect_from(&mut self, sink: &SpanSink) -> usize {
        let spans = sink.drain();
        self.record_all(&spans);
        spans.len()
    }

    /// Drain a span ring into the TSDB. Returns `(collected, dropped)`
    /// where `dropped` is the ring's cumulative overflow count — callers
    /// must surface it rather than silently undercounting.
    pub fn collect_from_ring(&mut self, consumer: &mut RingConsumer<Span>) -> (usize, u64) {
        let mut buf = Vec::new();
        consumer.drain_into(&mut buf);
        self.record_all(&buf);
        (buf.len(), consumer.dropped())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn span(stage: &'static str, start: f64, dur: f64, recs: u64, ok: bool) -> Span {
        Span {
            trace_id: 1,
            stage,
            start_s: start,
            duration_s: dur,
            ingest_s: f64::NAN,
            records: recs,
            bytes: recs * 100,
            ok,
        }
    }

    #[test]
    fn span_end_time() {
        assert_eq!(span("s", 2.0, 0.5, 1, true).end_s(), 2.5);
    }

    #[test]
    fn cum_latency_requires_known_ingest() {
        let mut s = span("s", 2.0, 0.5, 1, true);
        assert_eq!(s.cum_latency_s(), None);
        s.ingest_s = 1.0;
        assert_eq!(s.cum_latency_s(), Some(1.5));
    }

    #[test]
    fn sink_push_drain() {
        let sink = SpanSink::new();
        sink.push(span("a", 0.0, 1.0, 1, true));
        sink.push(span("b", 0.0, 1.0, 1, true));
        assert_eq!(sink.len(), 2);
        let drained = sink.drain();
        assert_eq!(drained.len(), 2);
        assert!(sink.is_empty());
    }

    #[test]
    fn collector_emits_per_stage_metrics() {
        let db = Tsdb::new();
        let c = Collector::new(db.clone());
        c.record(&span("etl", 1.0, 0.25, 5, true));
        let recs = db.samples("stage_records", &[("stage", "etl")]);
        assert_eq!(recs, vec![(1.25, 5.0)]);
        let lat = db.samples("stage_latency_s", &[("stage", "etl")]);
        assert_eq!(lat, vec![(1.25, 0.25)]);
        assert!(db.samples("stage_errors", &[("stage", "etl")]).is_empty());
        // no pipeline configured → no cumulative-latency series, even if
        // a span carries an ingest time
        let mut s = span("etl", 2.0, 0.25, 5, true);
        s.ingest_s = 0.0;
        c.record(&s);
        assert!(db.samples("stage_cum_latency_s", &[]).is_empty());
    }

    #[test]
    fn with_pipeline_derives_cum_latency() {
        let db = Tsdb::new();
        let c = Collector::with_pipeline(db.clone(), "demo");
        let mut s = span("etl", 3.0, 0.5, 1, true);
        s.ingest_s = 1.0;
        c.record(&s);
        c.record(&span("etl", 4.0, 0.5, 1, true)); // NaN ingest → skipped
        let cum = db.samples(
            "stage_cum_latency_s",
            &[("stage", "etl"), ("pipeline", "demo")],
        );
        assert_eq!(cum, vec![(3.5, 2.5)]);
    }

    #[test]
    fn collector_counts_errors() {
        let db = Tsdb::new();
        let c = Collector::new(db.clone());
        c.record(&span("v2x", 0.0, 0.1, 1, false));
        c.record(&span("v2x", 0.2, 0.1, 1, false));
        assert_eq!(db.sum_range("stage_errors", &[("stage", "v2x")], 0.0, 1.0), 2.0);
    }

    #[test]
    fn collect_from_drains_sink() {
        let db = Tsdb::new();
        let mut c = Collector::new(db.clone());
        let sink = SpanSink::new();
        for i in 0..10 {
            sink.push(span("u", i as f64, 0.5, 2, true));
        }
        assert_eq!(c.collect_from(&sink), 10);
        assert!(sink.is_empty());
        assert_eq!(db.sum_range("stage_records", &[("stage", "u")], 0.0, 100.0), 20.0);
    }

    #[test]
    fn record_all_matches_per_span_record() {
        let spans: Vec<Span> = (0..20)
            .map(|i| span(if i % 2 == 0 { "a" } else { "b" }, i as f64, 0.1, i, i % 5 != 0))
            .collect();
        let one = Tsdb::new();
        let c1 = Collector::new(one.clone());
        for s in &spans {
            c1.record(s);
        }
        let batch = Tsdb::new();
        let mut c2 = Collector::new(batch.clone());
        c2.record_all(&spans);
        for metric in ["stage_records", "stage_bytes", "stage_latency_s", "stage_errors"] {
            for stage in ["a", "b"] {
                assert_eq!(
                    one.samples(metric, &[("stage", stage)]),
                    batch.samples(metric, &[("stage", stage)]),
                    "{metric}/{stage} diverged"
                );
            }
        }
    }

    #[test]
    fn collect_from_ring_reports_drops() {
        let db = Tsdb::new();
        let mut c = Collector::new(db.clone());
        let (mut p, mut consumer) = super::super::ring::ring(4);
        for i in 0..6 {
            p.push(span("r", i as f64, 0.1, 1, true));
        }
        let (collected, dropped) = c.collect_from_ring(&mut consumer);
        assert_eq!((collected, dropped), (4, 2));
        assert_eq!(db.sum_range("stage_records", &[("stage", "r")], 0.0, 100.0), 4.0);
    }

    #[test]
    fn stages_do_not_mix() {
        let db = Tsdb::new();
        let c = Collector::new(db.clone());
        c.record(&span("a", 0.0, 0.1, 1, true));
        c.record(&span("b", 0.0, 0.2, 9, true));
        assert_eq!(db.sum_range("stage_records", &[("stage", "a")], 0.0, 10.0), 1.0);
        assert_eq!(db.sum_range("stage_records", &[("stage", "b")], 0.0, 10.0), 9.0);
    }
}

//! In-memory time-series database (the Prometheus stand-in).
//!
//! Series are identified by `(metric name, sorted label set)`. Samples are
//! `(virtual_time_s, value)` pairs appended in time order. The query
//! surface covers what PlantD's reports need: raw range reads, per-bucket
//! rates of cumulative counters, windowed sums, and quantiles.
//!
//! Ingest is the L3 hot path during an experiment (every span becomes a
//! handful of samples), so writers use a [`SeriesHandle`] — series lookup
//! happens once at registration, appends are a single short mutex hold.

use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Label set (sorted, so the key is canonical).
pub type Labels = BTreeMap<String, String>;

/// Canonical series identity.
#[derive(Debug, Clone, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct SeriesKey {
    /// Metric name, e.g. `stage_records`.
    pub name: String,
    /// Sorted label set.
    pub labels: Labels,
}

impl SeriesKey {
    /// Key from a name and label pairs.
    pub fn new(name: &str, labels: &[(&str, &str)]) -> Self {
        SeriesKey {
            name: name.to_string(),
            labels: labels
                .iter()
                .map(|(k, v)| (k.to_string(), v.to_string()))
                .collect(),
        }
    }

    /// Value of one label, if set.
    pub fn label(&self, key: &str) -> Option<&str> {
        self.labels.get(key).map(|s| s.as_str())
    }
}

type Samples = Arc<Mutex<Vec<(f64, f64)>>>;

/// Writer handle for one series: append without map lookups.
///
/// Non-finite timestamps or values (NaN, ±inf) are rejected at the door
/// and counted on the store's drop counter — a single poisoned sample
/// must never make every later range query panic in the sort.
#[derive(Debug, Clone)]
pub struct SeriesHandle {
    samples: Samples,
    dropped: Arc<AtomicU64>,
}

impl SeriesHandle {
    /// Append a sample. Caller supplies the (virtual) timestamp.
    /// Non-finite `t` or `v` is dropped (and counted), not stored.
    pub fn push(&self, t: f64, v: f64) {
        if !t.is_finite() || !v.is_finite() {
            self.dropped.fetch_add(1, Ordering::Relaxed);
            return;
        }
        self.samples.lock().unwrap().push((t, v));
    }

    /// Append many samples at once (single lock hold). Non-finite entries
    /// are dropped (and counted) individually; the rest are stored.
    pub fn push_batch(&self, batch: &[(f64, f64)]) {
        let bad = batch
            .iter()
            .filter(|(t, v)| !t.is_finite() || !v.is_finite())
            .count() as u64;
        if bad > 0 {
            self.dropped.fetch_add(bad, Ordering::Relaxed);
        }
        self.samples
            .lock()
            .unwrap()
            .extend(batch.iter().filter(|(t, v)| t.is_finite() && v.is_finite()));
    }
}

/// The store. Cheap to clone (`Arc` inside) — every component holds one.
#[derive(Debug, Clone, Default)]
pub struct Tsdb {
    inner: Arc<Mutex<BTreeMap<SeriesKey, Samples>>>,
    dropped: Arc<AtomicU64>,
}

impl Tsdb {
    /// Empty store.
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-create a series and return its writer handle.
    pub fn series(&self, name: &str, labels: &[(&str, &str)]) -> SeriesHandle {
        let key = SeriesKey::new(name, labels);
        let mut map = self.inner.lock().unwrap();
        let samples = map
            .entry(key)
            .or_insert_with(|| Arc::new(Mutex::new(Vec::new())))
            .clone();
        SeriesHandle {
            samples,
            dropped: self.dropped.clone(),
        }
    }

    /// One-shot write (registration + append). Convenient off the hot path.
    pub fn write(&self, name: &str, labels: &[(&str, &str)], t: f64, v: f64) {
        self.series(name, labels).push(t, v);
    }

    /// All series keys matching `name` and the given label constraints.
    pub fn keys(&self, name: &str, constraints: &[(&str, &str)]) -> Vec<SeriesKey> {
        let map = self.inner.lock().unwrap();
        map.keys()
            .filter(|k| {
                k.name == name
                    && constraints
                        .iter()
                        .all(|(lk, lv)| k.label(lk) == Some(*lv))
            })
            .cloned()
            .collect()
    }

    /// Raw samples of the first series matching name+constraints, sorted by
    /// time. Multiple matching series are merged (time-sorted).
    pub fn samples(&self, name: &str, constraints: &[(&str, &str)]) -> Vec<(f64, f64)> {
        let keys = self.keys(name, constraints);
        let map = self.inner.lock().unwrap();
        let mut out = Vec::new();
        for k in keys {
            if let Some(s) = map.get(&k) {
                out.extend_from_slice(&s.lock().unwrap());
            }
        }
        // total_cmp: a NaN timestamp (should be impossible — handles
        // reject them — but e.g. old snapshots could carry one) sorts
        // last instead of panicking the whole query surface
        out.sort_by(|a, b| a.0.total_cmp(&b.0));
        out
    }

    /// Sum of sample values in `[t0, t1]` across matching series.
    pub fn sum_range(
        &self,
        name: &str,
        constraints: &[(&str, &str)],
        t0: f64,
        t1: f64,
    ) -> f64 {
        self.samples(name, constraints)
            .iter()
            .filter(|(t, _)| *t >= t0 && *t <= t1)
            .map(|(_, v)| v)
            .sum()
    }

    /// Values (no timestamps) in a range — for quantile/mean folds.
    pub fn values_range(
        &self,
        name: &str,
        constraints: &[(&str, &str)],
        t0: f64,
        t1: f64,
    ) -> Vec<f64> {
        self.samples(name, constraints)
            .into_iter()
            .filter(|(t, _)| *t >= t0 && *t <= t1)
            .map(|(_, v)| v)
            .collect()
    }

    /// Bucketed event rate: samples are *increments* (e.g. records per
    /// span); returns `(bucket_center_t, sum/bucket_s)` per bucket covering
    /// `[t0, t1)`. This is how the Fig. 8 throughput curves are produced.
    pub fn rate(
        &self,
        name: &str,
        constraints: &[(&str, &str)],
        t0: f64,
        t1: f64,
        bucket_s: f64,
    ) -> Vec<(f64, f64)> {
        assert!(bucket_s > 0.0);
        let n = ((t1 - t0) / bucket_s).ceil().max(0.0) as usize;
        let mut sums = vec![0.0f64; n];
        for (t, v) in self.samples(name, constraints) {
            if t >= t0 && t < t1 {
                let idx = ((t - t0) / bucket_s) as usize;
                if idx < n {
                    sums[idx] += v;
                }
            }
        }
        sums.into_iter()
            .enumerate()
            .map(|(i, s)| (t0 + (i as f64 + 0.5) * bucket_s, s / bucket_s))
            .collect()
    }

    /// Bucketed mean of sample values (e.g. latency curves per stage).
    pub fn bucket_mean(
        &self,
        name: &str,
        constraints: &[(&str, &str)],
        t0: f64,
        t1: f64,
        bucket_s: f64,
    ) -> Vec<(f64, f64)> {
        assert!(bucket_s > 0.0);
        let n = ((t1 - t0) / bucket_s).ceil().max(0.0) as usize;
        let mut sums = vec![0.0f64; n];
        let mut counts = vec![0u64; n];
        for (t, v) in self.samples(name, constraints) {
            if t >= t0 && t < t1 {
                let idx = ((t - t0) / bucket_s) as usize;
                if idx < n {
                    sums[idx] += v;
                    counts[idx] += 1;
                }
            }
        }
        (0..n)
            .map(|i| {
                let mean = if counts[i] > 0 {
                    sums[i] / counts[i] as f64
                } else {
                    f64::NAN
                };
                (t0 + (i as f64 + 0.5) * bucket_s, mean)
            })
            .collect()
    }

    /// Latest sample time across all series (experiment drain detection).
    pub fn last_sample_time(&self) -> Option<f64> {
        let map = self.inner.lock().unwrap();
        map.values()
            .filter_map(|s| s.lock().unwrap().last().map(|(t, _)| *t))
            .fold(None, |acc, t| Some(acc.map_or(t, |a: f64| a.max(t))))
    }

    /// Total sample count (diagnostics / perf benches).
    pub fn total_samples(&self) -> usize {
        let map = self.inner.lock().unwrap();
        map.values().map(|s| s.lock().unwrap().len()).sum()
    }

    /// Samples rejected at ingest because the timestamp or value was
    /// non-finite. Survives [`Tsdb::clear`]: the count is a data-quality
    /// signal about the writers, not about the stored data.
    pub fn dropped_samples(&self) -> u64 {
        self.dropped.load(Ordering::Relaxed)
    }

    /// Drop all data (between experiments on a shared harness).
    pub fn clear(&self) {
        self.inner.lock().unwrap().clear();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn write_and_read_back() {
        let db = Tsdb::new();
        db.write("records_total", &[("stage", "etl")], 1.0, 5.0);
        db.write("records_total", &[("stage", "etl")], 2.0, 7.0);
        let s = db.samples("records_total", &[("stage", "etl")]);
        assert_eq!(s, vec![(1.0, 5.0), (2.0, 7.0)]);
    }

    #[test]
    fn label_constraints_filter() {
        let db = Tsdb::new();
        db.write("m", &[("stage", "a")], 1.0, 1.0);
        db.write("m", &[("stage", "b")], 1.0, 2.0);
        assert_eq!(db.samples("m", &[("stage", "a")]).len(), 1);
        // no constraints: both series merged
        assert_eq!(db.samples("m", &[]).len(), 2);
        assert_eq!(db.samples("m", &[("stage", "zzz")]).len(), 0);
    }

    #[test]
    fn merged_samples_are_time_sorted() {
        let db = Tsdb::new();
        db.write("m", &[("s", "a")], 5.0, 1.0);
        db.write("m", &[("s", "b")], 1.0, 2.0);
        db.write("m", &[("s", "a")], 9.0, 3.0);
        let times: Vec<f64> = db.samples("m", &[]).iter().map(|(t, _)| *t).collect();
        assert_eq!(times, vec![1.0, 5.0, 9.0]);
    }

    #[test]
    fn handle_appends_fast_path() {
        let db = Tsdb::new();
        let h = db.series("m", &[("w", "1")]);
        h.push(0.0, 1.0);
        h.push_batch(&[(1.0, 2.0), (2.0, 3.0)]);
        assert_eq!(db.samples("m", &[]).len(), 3);
        assert_eq!(db.total_samples(), 3);
    }

    #[test]
    fn sum_range_is_inclusive() {
        let db = Tsdb::new();
        for t in 0..10 {
            db.write("m", &[], t as f64, 1.0);
        }
        assert_eq!(db.sum_range("m", &[], 2.0, 5.0), 4.0);
    }

    #[test]
    fn rate_buckets() {
        let db = Tsdb::new();
        // 10 records at t=0.5, 20 at t=1.5 → rates 10/s then 20/s with 1s buckets
        db.write("recs", &[], 0.5, 10.0);
        db.write("recs", &[], 1.5, 20.0);
        let r = db.rate("recs", &[], 0.0, 2.0, 1.0);
        assert_eq!(r.len(), 2);
        assert_eq!(r[0], (0.5, 10.0));
        assert_eq!(r[1], (1.5, 20.0));
    }

    #[test]
    fn rate_excludes_out_of_range() {
        let db = Tsdb::new();
        db.write("recs", &[], -1.0, 100.0);
        db.write("recs", &[], 5.0, 100.0);
        let r = db.rate("recs", &[], 0.0, 2.0, 1.0);
        assert!(r.iter().all(|(_, v)| *v == 0.0));
    }

    #[test]
    fn bucket_mean_handles_empty_buckets() {
        let db = Tsdb::new();
        db.write("lat", &[], 0.5, 2.0);
        db.write("lat", &[], 0.6, 4.0);
        let m = db.bucket_mean("lat", &[], 0.0, 2.0, 1.0);
        assert_eq!(m[0].1, 3.0);
        assert!(m[1].1.is_nan());
    }

    #[test]
    fn last_sample_time_tracks_max() {
        let db = Tsdb::new();
        assert_eq!(db.last_sample_time(), None);
        db.write("a", &[], 3.0, 1.0);
        db.write("b", &[], 7.0, 1.0);
        db.write("a", &[], 5.0, 1.0);
        assert_eq!(db.last_sample_time(), Some(7.0));
    }

    #[test]
    fn clear_empties() {
        let db = Tsdb::new();
        db.write("a", &[], 1.0, 1.0);
        db.clear();
        assert_eq!(db.total_samples(), 0);
    }

    #[test]
    fn non_finite_samples_are_dropped_and_counted() {
        let db = Tsdb::new();
        let h = db.series("m", &[]);
        h.push(f64::NAN, 1.0);
        h.push(1.0, f64::NAN);
        h.push(f64::INFINITY, 1.0);
        h.push(2.0, f64::NEG_INFINITY);
        h.push(3.0, 4.0);
        h.push_batch(&[(4.0, 1.0), (f64::NAN, f64::NAN), (5.0, 2.0)]);
        assert_eq!(db.dropped_samples(), 5);
        assert_eq!(db.samples("m", &[]), vec![(3.0, 4.0), (4.0, 1.0), (5.0, 2.0)]);
        // range queries over the store still work — the regression this
        // guards: one NaN timestamp used to panic every later query
        assert_eq!(db.sum_range("m", &[], 0.0, 10.0), 7.0);
    }

    #[test]
    fn query_survives_nan_bearing_series() {
        // simulate a store that somehow holds a NaN timestamp anyway
        // (e.g. loaded from an old snapshot): sorting must not panic
        let db = Tsdb::new();
        let key = SeriesKey::new("m", &[]);
        db.inner
            .lock()
            .unwrap()
            .entry(key)
            .or_insert_with(|| Arc::new(Mutex::new(Vec::new())))
            .lock()
            .unwrap()
            .extend_from_slice(&[(2.0, 1.0), (f64::NAN, 9.0), (1.0, 3.0)]);
        let s = db.samples("m", &[]);
        assert_eq!(s.len(), 3);
        assert_eq!((s[0], s[1]), ((1.0, 3.0), (2.0, 1.0)));
        assert!(s[2].0.is_nan());
        // the NaN sample fails every range predicate, so folds stay finite
        assert_eq!(db.sum_range("m", &[], 0.0, 10.0), 4.0);
    }

    #[test]
    fn concurrent_writers() {
        let db = Tsdb::new();
        let mut handles = Vec::new();
        for w in 0..4 {
            let h = db.series("m", &[("worker", &w.to_string())]);
            handles.push(std::thread::spawn(move || {
                for i in 0..1000 {
                    h.push(i as f64, 1.0);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(db.total_samples(), 4000);
    }
}

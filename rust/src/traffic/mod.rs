//! Traffic models: projected business load for a future year (§V.G).
//!
//! A [`TrafficModel`] holds the four inputs the paper's analysts supply:
//! the base data rate `R` (records/second at the start of the year), the
//! annual growth factor `G` (1.0 = no growth — the §V.G formula uses the
//! *net* growth `G − 1`, see DESIGN.md §3), 12 monthly correction factors,
//! and 168 hour-of-week correction factors.
//!
//! `project_hourly` is the pure-Rust evaluator of the projection (the
//! cross-check for the AOT `traffic.hlo.txt` artifact, and the fallback
//! when PJRT is unavailable). Calendar conventions are identical to
//! `python/compile/kernels/ref.py`: 365-day year, Jan 1 falls on Monday,
//! hour-of-week index = dow·24 + hour.

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Hours in the projected (non-leap) year.
pub const HOURS_PER_YEAR: usize = 8760;
/// Days in the projected (non-leap) year.
pub const DAYS_PER_YEAR: usize = 365;

/// Cumulative days at the start of each month (non-leap).
pub const MONTH_STARTS: [u32; 12] = [0, 31, 59, 90, 120, 151, 181, 212, 243, 273, 304, 334];

/// Day-of-year (0-based) for an hour index.
pub fn day_of_year(hour: usize) -> usize {
    (hour / 24) % DAYS_PER_YEAR
}

/// Month (0..11) for an hour index.
pub fn month_of_hour(hour: usize) -> usize {
    let doy = day_of_year(hour) as u32;
    match MONTH_STARTS.binary_search(&doy) {
        Ok(m) => m,
        Err(ins) => ins - 1,
    }
}

/// Hour-of-week (0..167) for an hour index; week starts Monday 00:00.
pub fn hour_of_week(hour: usize) -> usize {
    let dow = (hour / 24) % 7;
    dow * 24 + (hour % 24)
}

/// The analyst-supplied traffic forecast.
#[derive(Debug, Clone)]
pub struct TrafficModel {
    /// Forecast name (e.g. "Nominal", "High").
    pub name: String,
    /// Records per second at the start of the year.
    pub base_rps: f64,
    /// Annual growth factor: 1.0 = flat, 1.5 = +50 % by year end.
    pub growth_factor: f64,
    /// Seasonal correction per month.
    pub month_f: [f64; 12],
    /// Correction per hour of the calendar week.
    pub hw_f: [f64; 168],
    /// Optional short-term burstiness (the paper's §IX future-work item:
    /// "statistically characterizing burstiness of real-world traffic, to
    /// model very short-term peaks"). Applied multiplicatively per hour.
    pub burst: Option<BurstSpec>,
}

/// Multiplicative per-hour burst model: with probability `prob` an hour's
/// load is multiplied by `magnitude` (deterministic in `seed`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct BurstSpec {
    /// Probability an hour bursts.
    pub prob: f64,
    /// Multiplier applied to a bursting hour's load.
    pub magnitude: f64,
    /// PRNG seed (bursts replay deterministically).
    pub seed: u64,
}

impl TrafficModel {
    /// Net growth `g = G − 1` used by the formula.
    pub fn growth_net(&self) -> f64 {
        self.growth_factor - 1.0
    }

    /// The §V.G projection: records/hour for each hour of the year
    /// (plus bursts, if configured).
    pub fn project_hourly(&self) -> Vec<f64> {
        let mut load: Vec<f64> = (0..HOURS_PER_YEAR)
            .map(|h| {
                let doy = day_of_year(h) as f64;
                self.base_rps
                    * 3600.0
                    * (1.0 + doy * self.growth_net() / DAYS_PER_YEAR as f64)
                    * self.hw_f[hour_of_week(h)]
                    * self.month_f[month_of_hour(h)]
            })
            .collect();
        if let Some(b) = &self.burst {
            apply_bursts(&mut load, b);
        }
        load
    }

    /// Derive a bursty variant of this forecast.
    pub fn with_bursts(&self, prob: f64, magnitude: f64, seed: u64) -> Self {
        TrafficModel {
            name: format!("{}+bursts", self.name),
            burst: Some(BurstSpec {
                prob,
                magnitude,
                seed,
            }),
            ..self.clone()
        }
    }

    /// Mean offered load, records/hour.
    pub fn mean_load_rec_hr(&self) -> f64 {
        self.project_hourly().iter().sum::<f64>() / HOURS_PER_YEAR as f64
    }

    /// Carve a window of the hourly projection into a
    /// [`crate::loadgen::LoadPattern`]: one piecewise-linear segment per
    /// hour, interpolating between consecutive hourly loads (the last
    /// hour holds its rate). This is how a business forecast becomes a
    /// *load case*: the resulting pattern is consumed identically by the
    /// wall-clock load generator, the campaign engine, and the
    /// [`crate::sim`] kernel — twin scenarios and wind-tunnel runs then
    /// share one arrival schedule.
    pub fn to_load_pattern(
        &self,
        start_hour: usize,
        hours: usize,
    ) -> crate::loadgen::LoadPattern {
        assert!(hours >= 1, "need at least one hour");
        assert!(
            start_hour + hours <= HOURS_PER_YEAR,
            "window [{start_hour}, {}) exceeds the projected year",
            start_hour + hours
        );
        let load = self.project_hourly();
        let segments = (start_hour..start_hour + hours)
            .map(|h| {
                let start_rps = load[h] / 3600.0;
                let end_rps = if h + 1 < HOURS_PER_YEAR {
                    load[h + 1] / 3600.0
                } else {
                    start_rps
                };
                crate::loadgen::Segment {
                    duration_s: 3600.0,
                    start_rps,
                    end_rps,
                }
            })
            .collect();
        crate::loadgen::LoadPattern::new(segments)
    }

    /// The paper's *Nominal* projection: 250 k instrumented cars, 50 %
    /// telematics opt-in, ~4 % on the road at any time, one transmission
    /// per driving hour → ≈ 5000 records/hour average; no net growth.
    /// (§VI.B; the 3.5 rps figure of §VI.D is the pre-correction base.)
    pub fn nominal() -> Self {
        TrafficModel {
            name: "Nominal".into(),
            base_rps: 3.5,
            growth_factor: 1.0,
            month_f: honda_month_factors(),
            hw_f: honda_hour_of_week_factors(),
            burst: None,
        }
    }

    /// The paper's *High* projection: same start, 50 % growth in installed
    /// vehicles over the year.
    pub fn high() -> Self {
        TrafficModel {
            name: "High".into(),
            growth_factor: 1.5,
            ..Self::nominal()
        }
    }

    /// Parse from JSON:
    /// `{"name": .., "base_rps": .., "growth_factor": ..,
    ///   "month_f": [12 floats]?, "hw_f": [168 floats]?}`
    /// (factor arrays default to the Honda-derived presets).
    pub fn from_json(j: &Json) -> Result<TrafficModel, String> {
        let mut m = Self::nominal();
        m.name = j
            .get("name")
            .and_then(Json::as_str)
            .unwrap_or("custom")
            .to_string();
        if let Some(v) = j.get("base_rps").and_then(Json::as_f64) {
            m.base_rps = v;
        }
        if let Some(v) = j.get("growth_factor").and_then(Json::as_f64) {
            m.growth_factor = v;
        }
        if let Some(arr) = j.get("month_f").and_then(Json::as_arr) {
            if arr.len() != 12 {
                return Err(format!("month_f needs 12 entries, got {}", arr.len()));
            }
            for (i, v) in arr.iter().enumerate() {
                m.month_f[i] = v.as_f64().ok_or("month_f: non-number")?;
            }
        }
        if let Some(arr) = j.get("hw_f").and_then(Json::as_arr) {
            if arr.len() != 168 {
                return Err(format!("hw_f needs 168 entries, got {}", arr.len()));
            }
            for (i, v) in arr.iter().enumerate() {
                m.hw_f[i] = v.as_f64().ok_or("hw_f: non-number")?;
            }
        }
        if let Some(b) = j.get("burst") {
            let get = |k: &str| -> Result<f64, String> {
                b.get(k)
                    .and_then(Json::as_f64)
                    .ok_or_else(|| format!("burst: missing '{k}'"))
            };
            let prob = get("prob")?;
            let magnitude = get("magnitude")?;
            if !(0.0..=1.0).contains(&prob) || magnitude < 0.0 {
                return Err("burst: need 0 <= prob <= 1 and magnitude >= 0".into());
            }
            // string form ("0x…"/decimal) carries the full u64 range;
            // a malformed seed is an error, not a silent 0
            let seed = match b.get("seed") {
                None => 0,
                Some(v) => crate::util::cli::seed_from_json(v)
                    .ok_or("burst: seed must be an integer or seed string")?,
            };
            m.burst = Some(BurstSpec {
                prob,
                magnitude,
                seed,
            });
        }
        Ok(m)
    }

    /// Serialize to the JSON spec form [`TrafficModel::from_json`] parses.
    /// The factor arrays are always emitted explicitly, so
    /// serialize → parse → serialize is a fixed point.
    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("name", Json::str(self.name.clone())),
            ("base_rps", Json::Num(self.base_rps)),
            ("growth_factor", Json::Num(self.growth_factor)),
            ("month_f", Json::arr(self.month_f.iter().map(|&v| Json::Num(v)))),
            ("hw_f", Json::arr(self.hw_f.iter().map(|&v| Json::Num(v)))),
        ];
        if let Some(b) = &self.burst {
            pairs.push((
                "burst",
                Json::obj(vec![
                    ("prob", Json::Num(b.prob)),
                    ("magnitude", Json::Num(b.magnitude)),
                    ("seed", Json::str(format!("{:#x}", b.seed))),
                ]),
            ));
        }
        Json::obj(pairs)
    }
}

/// Apply a burst spec in place (deterministic in its seed).
pub fn apply_bursts(load: &mut [f64], spec: &BurstSpec) {
    assert!(spec.prob >= 0.0 && spec.prob <= 1.0 && spec.magnitude >= 0.0);
    let mut rng = Rng::new(spec.seed);
    for v in load.iter_mut() {
        if rng.chance(spec.prob) {
            *v *= spec.magnitude;
        }
    }
}

/// Monthly correction factors "abstracted from measurements from a Honda
/// test program" (§VI.B): 0.84 in January up to 1.14 in August.
pub fn honda_month_factors() -> [f64; 12] {
    [
        0.84, 0.86, 0.93, 0.98, 1.04, 1.08, 1.12, 1.14, 1.06, 0.99, 0.91, 0.87,
    ]
}

/// Hour-of-week correction factors (Monday 00:00 first), anchored to the
/// paper's extremes: 2.26 on Friday at 20:00, 0.04 on Wednesday at 06:00.
///
/// Shape: deep night trough, commute shoulders, moderate weekday evening
/// peak, plus a pronounced Friday-night (and smaller Saturday-night)
/// surge — the surge hours carry the paper's 2.26 maximum while weekday
/// evenings stay only modestly above the blocking pipeline's capacity,
/// which is what makes Fig. 7's "can't quite keep up at the peak, recovers
/// at night" dynamic (and Table II's barely-met SLO) come out right.
pub fn honda_hour_of_week_factors() -> [f64; 168] {
    // base diurnal curve (24 values, weekday template)
    const DAY: [f64; 24] = [
        0.10, 0.07, 0.055, 0.05, 0.046, 0.045, 0.044, 0.09, 0.18, 0.30, 0.42,
        0.50, 0.54, 0.52, 0.48, 0.50, 0.58, 0.72, 0.95, 1.08, 1.10, 0.80, 0.40,
        0.18,
    ];
    // per-day multiplier, Monday..Sunday (weekends slightly damped so the
    // Friday-night backlog can drain before the Saturday surge)
    const DOW: [f64; 7] = [0.96, 0.94, 0.92, 0.95, 1.02, 0.93, 0.95];
    let mut out = [0.0; 168];
    for d in 0..7 {
        for h in 0..24 {
            out[d * 24 + h] = DAY[h] * DOW[d];
        }
    }
    // Friday/Saturday night surge (anchor: Fri 20:00 = 2.26)
    out[4 * 24 + 19] = 1.55;
    out[4 * 24 + 20] = 2.26;
    out[4 * 24 + 21] = 1.30;
    out[5 * 24 + 20] = 1.45;
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calendar_helpers() {
        assert_eq!(day_of_year(0), 0);
        assert_eq!(day_of_year(8759), 364);
        assert_eq!(month_of_hour(0), 0);
        assert_eq!(month_of_hour(31 * 24), 1); // Feb 1
        assert_eq!(month_of_hour(8759), 11);
        assert_eq!(hour_of_week(0), 0);
        assert_eq!(hour_of_week(25), 25); // Tue 01:00
        assert_eq!(hour_of_week(7 * 24), 0); // next Monday
    }

    #[test]
    fn factor_anchors_match_paper() {
        let m = honda_month_factors();
        assert_eq!(m[0], 0.84); // January
        assert_eq!(m[7], 1.14); // August
        assert!(m.iter().all(|&v| (0.84..=1.14).contains(&v)));
        let h = honda_hour_of_week_factors();
        // Friday 20:00 = dow 4
        let fri8pm = h[4 * 24 + 20];
        assert!((fri8pm - 2.26).abs() < 0.01, "fri 20:00 = {fri8pm}");
        // Wednesday 06:00 = dow 2
        let wed6am = h[2 * 24 + 6];
        assert!((wed6am - 0.04).abs() < 0.001, "wed 06:00 = {wed6am}");
        // extremes are the global extremes
        let max = h.iter().cloned().fold(f64::MIN, f64::max);
        let min = h.iter().cloned().fold(f64::MAX, f64::min);
        assert_eq!(max, fri8pm);
        assert_eq!(min, wed6am);
    }

    #[test]
    fn to_load_pattern_tracks_the_projection() {
        let m = TrafficModel::nominal();
        let load = m.project_hourly();
        // a Friday-evening window (first Friday, 18:00–22:00)
        let start = 4 * 24 + 18;
        let p = m.to_load_pattern(start, 4);
        assert_eq!(p.segments.len(), 4);
        assert!((p.total_duration_s() - 4.0 * 3600.0).abs() < 1e-6);
        // rates are the hourly projection divided into rec/s
        assert!((p.segments[0].start_rps - load[start] / 3600.0).abs() < 1e-12);
        assert!((p.segments[0].end_rps - load[start + 1] / 3600.0).abs() < 1e-12);
        // total offered records ≈ trapezoidal integral of the window
        let area: f64 = (start..start + 4)
            .map(|h| (load[h] + load[h + 1]) / 2.0)
            .sum();
        let offered = p.total_records() as f64;
        assert!((offered - area).abs() <= 1.0, "offered {offered} vs {area}");
        // the arrival stream is consumable like any other pattern
        let times: Vec<f64> = p.arrivals().collect();
        assert_eq!(times.len() as u64, p.total_records());
        assert!(times.windows(2).all(|w| w[1] >= w[0]));
    }

    #[test]
    #[should_panic(expected = "exceeds the projected year")]
    fn to_load_pattern_rejects_out_of_year_window() {
        TrafficModel::nominal().to_load_pattern(HOURS_PER_YEAR - 2, 3);
    }

    #[test]
    fn projection_length_and_positivity() {
        let load = TrafficModel::nominal().project_hourly();
        assert_eq!(load.len(), HOURS_PER_YEAR);
        assert!(load.iter().all(|&v| v >= 0.0));
    }

    #[test]
    fn nominal_mean_load_near_5000_rec_hr() {
        // the paper's back-of-envelope: ≈ 5000 records/hour on average
        let mean = TrafficModel::nominal().mean_load_rec_hr();
        assert!(
            (4200.0..6000.0).contains(&mean),
            "nominal mean {mean} rec/hr"
        );
    }

    #[test]
    fn no_growth_means_weekly_periodicity_within_month() {
        let m = TrafficModel::nominal();
        let load = m.project_hourly();
        // two consecutive weeks fully inside January differ only by 0 growth
        for h in 0..168 {
            let a = load[h];
            let b = load[h + 168];
            assert!((a - b).abs() < 1e-9, "h={h}: {a} vs {b}");
        }
    }

    #[test]
    fn high_projection_grows_50pct() {
        let hi = TrafficModel::high();
        let load = hi.project_hourly();
        // same hour-of-week and month at start vs end of year:
        // compare first Monday of January vs same structure scaled.
        // End-of-year growth multiplier is 1 + 364/365*0.5 ≈ 1.4986.
        let nominal = TrafficModel::nominal().project_hourly();
        let ratio = load[8750] / nominal[8750];
        assert!((ratio - (1.0 + 364.0 / 365.0 * 0.5)).abs() < 1e-6);
        assert!((load[10] / nominal[10] - 1.0).abs() < 1e-3);
    }

    #[test]
    fn growth_is_linear_in_day_of_year() {
        let m = TrafficModel {
            name: "g".into(),
            base_rps: 1.0,
            growth_factor: 2.0,
            month_f: [1.0; 12],
            hw_f: [1.0; 168],
            burst: None,
        };
        let load = m.project_hourly();
        assert!((load[0] - 3600.0).abs() < 1e-9);
        let mid = load[182 * 24]; // day 182
        assert!((mid - 3600.0 * (1.0 + 182.0 / 365.0)).abs() < 1e-6);
    }

    #[test]
    fn from_json_defaults_and_overrides() {
        let j = Json::parse(r#"{"name": "x", "base_rps": 7.0, "growth_factor": 1.2}"#)
            .unwrap();
        let m = TrafficModel::from_json(&j).unwrap();
        assert_eq!(m.base_rps, 7.0);
        assert!((m.growth_net() - 0.2).abs() < 1e-12);
        assert_eq!(m.month_f, honda_month_factors());
        let bad = Json::parse(r#"{"month_f": [1, 2]}"#).unwrap();
        assert!(TrafficModel::from_json(&bad).is_err());
    }

    #[test]
    fn to_json_roundtrip_is_a_fixed_point() {
        for m in [
            TrafficModel::nominal(),
            TrafficModel::high(),
            TrafficModel::nominal().with_bursts(0.1, 3.0, 77),
        ] {
            let j1 = m.to_json();
            let back = TrafficModel::from_json(&j1).unwrap();
            assert_eq!(back.name, m.name);
            assert_eq!(back.burst, m.burst);
            assert_eq!(back.project_hourly(), m.project_hourly());
            assert_eq!(j1.to_string_pretty(), back.to_json().to_string_pretty());
        }
    }

    #[test]
    fn from_json_rejects_bad_burst() {
        let bad = Json::parse(r#"{"burst": {"prob": 1.5, "magnitude": 2}}"#).unwrap();
        assert!(TrafficModel::from_json(&bad).is_err());
        let missing = Json::parse(r#"{"burst": {"prob": 0.5}}"#).unwrap();
        assert!(TrafficModel::from_json(&missing).is_err());
        // a malformed seed errors instead of silently becoming 0
        let typo = Json::parse(
            r#"{"burst": {"prob": 0.5, "magnitude": 2, "seed": "sead-typo"}}"#,
        )
        .unwrap();
        assert!(TrafficModel::from_json(&typo).is_err());
        // and the full-u64 string form round-trips
        let big = Json::parse(
            r#"{"burst": {"prob": 0.5, "magnitude": 2, "seed": "0xDEADBEEFDEADBEEF"}}"#,
        )
        .unwrap();
        let m = TrafficModel::from_json(&big).unwrap();
        assert_eq!(m.burst.unwrap().seed, 0xDEAD_BEEF_DEAD_BEEF);
    }

    #[test]
    fn bursts_are_deterministic_and_scale_mean() {
        let base = TrafficModel::nominal();
        let bursty = base.with_bursts(0.1, 3.0, 77);
        let a = bursty.project_hourly();
        let b = bursty.project_hourly();
        assert_eq!(a, b, "bursts must replay deterministically");
        let m0 = base.mean_load_rec_hr();
        let m1 = a.iter().sum::<f64>() / a.len() as f64;
        // E[mult] = 1 + prob*(mag-1) = 1.2
        assert!((m1 / m0 - 1.2).abs() < 0.05, "ratio {}", m1 / m0);
        // every bursty hour is either 1x or 3x the base hour
        let base_load = base.project_hourly();
        for (x, y) in a.iter().zip(&base_load) {
            let r = x / y;
            assert!((r - 1.0).abs() < 1e-9 || (r - 3.0).abs() < 1e-9, "r={r}");
        }
    }

    #[test]
    fn zero_prob_bursts_are_identity() {
        let base = TrafficModel::nominal();
        let same = base.with_bursts(0.0, 10.0, 1);
        assert_eq!(base.project_hourly(), same.project_hourly());
    }

    #[test]
    fn mean_matches_hand_rolled_average() {
        let m = TrafficModel::nominal();
        let load = m.project_hourly();
        let mean = load.iter().sum::<f64>() / load.len() as f64;
        assert!((m.mean_load_rec_hr() - mean).abs() < 1e-9);
    }
}

//! Digital twins: mathematical models of a measured pipeline (§V.G).
//!
//! A [`TwinParams`] is a Table I row — the explainable parameters PlantD
//! fits from one experiment: sustained capacity, fixed $/hr, no-queue
//! latency, FIFO policy. Two predefined twin types (the paper's):
//!
//! - [`TwinKind::Simple`]       — fixed throughput capacity, infinite FIFO
//!   queue (evaluated by the AOT queue-scan kernel through `runtime`);
//! - [`TwinKind::Quickscaling`] — optimal horizontal scaling: no queue
//!   ever forms; cost scales with the replica count needed each hour.
//!
//! "No synthetic data is actually processed; only the load shape is used,
//! so the simulation is quite fast" — the twin consumes only projections.

use crate::experiment::ExperimentRecord;
use crate::util::json::Json;

/// Twin model family.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum TwinKind {
    /// Fixed capacity + infinite FIFO queue.
    Simple,
    /// Optimal horizontal scaling, no queueing delays.
    Quickscaling,
    /// Reactive horizontal scaling with lag — the paper's §VI.C
    /// future-work item ("autoscaling behavior could be predicted by
    /// wrapping a fixed model based on measurements with autoscaling
    /// rules"), and §VII.B's suggestion that autoscaling the cheap
    /// pipeline might beat the fast one.
    Autoscaling(AutoscalePolicy),
}

/// Reactive autoscaler: replica count adjusts once per simulated hour
/// based on the previous hour's utilization (processed / capacity) and
/// backlog, like a conservative HPA.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AutoscalePolicy {
    /// Floor on replica count.
    pub min_replicas: u32,
    /// Ceiling on replica count.
    pub max_replicas: u32,
    /// Scale up when utilization exceeds this (or any backlog remains).
    pub scale_up_util: f64,
    /// Scale down when utilization falls below this and no backlog.
    pub scale_down_util: f64,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            min_replicas: 1,
            max_replicas: 8,
            scale_up_util: 0.85,
            scale_down_util: 0.30,
        }
    }
}

impl TwinKind {
    /// Stable lowercase name (used in JSON and reports).
    pub fn as_str(&self) -> &'static str {
        match self {
            TwinKind::Simple => "simple",
            TwinKind::Quickscaling => "quickscaling",
            TwinKind::Autoscaling(_) => "autoscaling",
        }
    }
}

/// The fitted parameters of a digital twin (Table I).
#[derive(Debug, Clone)]
pub struct TwinParams {
    /// Name of the pipeline variant this twin models.
    pub name: String,
    /// Model family (fixed / quickscaling / autoscaling).
    pub kind: TwinKind,
    /// Sustained ingest capacity, records/second ("max rec/s").
    pub max_rps: f64,
    /// Fixed resource cost per hour, USD ("$/hr"; the paper prints cents).
    pub cost_per_hr: f64,
    /// Per-record processing latency with no queuing, seconds.
    pub avg_latency_s: f64,
    /// Queue discipline (always FIFO in the paper).
    pub policy: &'static str,
}

impl TwinParams {
    /// Fit a Simple twin from one experiment record — the paper's
    /// proof-of-concept model: "uses the total time to fully process all
    /// the records in the generated load, and calculates the apparent
    /// sustained throughput".
    pub fn fit(record: &ExperimentRecord) -> TwinParams {
        TwinParams {
            name: record.variant.to_string(),
            kind: TwinKind::Simple,
            max_rps: record.zips_sent as f64 / record.duration_s,
            cost_per_hr: record.cost_per_hr_usd,
            avg_latency_s: record.latency_nq_mean_s,
            policy: "fifo",
        }
    }

    /// The same parameters reinterpreted as a Quickscaling twin.
    pub fn as_quickscaling(&self) -> TwinParams {
        TwinParams {
            kind: TwinKind::Quickscaling,
            ..self.clone()
        }
    }

    /// The same parameters wrapped in reactive autoscaling rules.
    pub fn as_autoscaling(&self, policy: AutoscalePolicy) -> TwinParams {
        TwinParams {
            kind: TwinKind::Autoscaling(policy),
            ..self.clone()
        }
    }

    /// Cost per processed record at full utilization — the paper's §VI.C
    /// "dividing those two parameters" comparison.
    pub fn cost_per_record(&self) -> f64 {
        self.cost_per_hr / (self.max_rps * 3600.0)
    }

    /// Serialize to the DigitalTwin resource's JSON spec form.
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("kind", Json::str(self.kind.as_str())),
            ("max_rps", Json::num(self.max_rps)),
            ("cost_per_hr", Json::num(self.cost_per_hr)),
            ("avg_latency_s", Json::num(self.avg_latency_s)),
            ("policy", Json::str(self.policy)),
        ])
    }

    /// Parse from the JSON spec form produced by [`TwinParams::to_json`].
    pub fn from_json(j: &Json) -> Result<TwinParams, String> {
        let get = |k: &str| -> Result<f64, String> {
            j.get(k)
                .and_then(Json::as_f64)
                .ok_or_else(|| format!("twin: missing '{k}'"))
        };
        let kind = match j.get("kind").and_then(Json::as_str).unwrap_or("simple") {
            "simple" => TwinKind::Simple,
            "quickscaling" => TwinKind::Quickscaling,
            other => return Err(format!("twin: unknown kind '{other}'")),
        };
        Ok(TwinParams {
            name: j
                .get("name")
                .and_then(Json::as_str)
                .unwrap_or("unnamed")
                .to_string(),
            kind,
            max_rps: get("max_rps")?,
            cost_per_hr: get("cost_per_hr")?,
            avg_latency_s: get("avg_latency_s")?,
            policy: "fifo",
        })
    }

    /// The paper's three Table I twins, as published (for benches that
    /// regenerate Table II without re-running the wind tunnel).
    pub fn paper_table1() -> Vec<TwinParams> {
        let mk = |name: &str, max_rps: f64, cents_hr: f64, lat: f64| TwinParams {
            name: name.to_string(),
            kind: TwinKind::Simple,
            max_rps,
            cost_per_hr: cents_hr / 100.0,
            avg_latency_s: lat,
            policy: "fifo",
        };
        vec![
            mk("blocking-write", 1.95, 0.82, 0.15),
            mk("no-blocking-write", 6.15, 7.03, 0.06),
            mk("cpu-limited", 0.66, 0.27, 0.29),
        ]
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::datagen::{DataSet, DataSetSpec};
    use crate::experiment::{Experiment, ExperimentHarness};
    use crate::loadgen::LoadPattern;
    use crate::pipeline::VariantConfig;

    #[test]
    fn paper_table1_values() {
        let twins = TwinParams::paper_table1();
        assert_eq!(twins.len(), 3);
        assert_eq!(twins[0].max_rps, 1.95);
        assert!((twins[1].cost_per_hr - 0.0703).abs() < 1e-12);
        assert_eq!(twins[2].avg_latency_s, 0.29);
    }

    #[test]
    fn cost_per_record_ordering_matches_paper() {
        // §VI.C: no-blocking ≈ $0.00032/record, ~3× blocking ($0.00012),
        // cpu-limited ≈ $0.00011. (Those dollar figures take the paper's
        // ¢/hr column as $/hr; we reproduce the *ratios* with the honest
        // units.)
        let twins = TwinParams::paper_table1();
        let per_rec: Vec<f64> = twins.iter().map(|t| t.cost_per_record()).collect();
        let ratio = per_rec[1] / per_rec[0];
        assert!((2.0..4.0).contains(&ratio), "ratio {ratio}");
        assert!(per_rec[2] < per_rec[0]);
    }

    #[test]
    fn json_roundtrip() {
        let t = &TwinParams::paper_table1()[0];
        let j = t.to_json();
        let back = TwinParams::from_json(&j).unwrap();
        assert_eq!(back.name, t.name);
        assert_eq!(back.kind, TwinKind::Simple);
        assert!((back.max_rps - t.max_rps).abs() < 1e-12);
        assert!(TwinParams::from_json(&Json::parse("{}").unwrap()).is_err());
    }

    #[test]
    fn quickscaling_reinterpretation() {
        let t = TwinParams::paper_table1()[0].as_quickscaling();
        assert_eq!(t.kind, TwinKind::Quickscaling);
        assert_eq!(t.max_rps, 1.95);
    }

    #[test]
    fn fit_recovers_capacity_from_saturating_experiment() {
        // Saturate the cpu-limited variant (cheapest to drain: few zips)
        // moderate scale: see experiment::tests for the rationale
        let harness = ExperimentHarness::new(300.0);
        let exp = Experiment::new(
            "fit-test",
            LoadPattern::steady(6.0, 4.0), // 24 zips ≫ 0.66 z/s
            DataSet::generate(DataSetSpec {
                payloads: 8,
                records_per_subsystem: 4,
                bad_rate: 0.0,
                seed: 4,
            }),
        );
        let cfg = VariantConfig::cpu_limited();
        let rec = harness.run(&cfg, &exp).unwrap();
        let twin = TwinParams::fit(&rec);
        let analytic = cfg.analytic_capacity_zps();
        assert!(
            (twin.max_rps / analytic - 1.0).abs() < 0.35,
            "fit {} vs analytic {analytic}",
            twin.max_rps
        );
        assert_eq!(twin.policy, "fifo");
        assert!(twin.avg_latency_s > 0.0);
        assert!((twin.cost_per_hr - cfg.cost_per_hr(&harness.prices)).abs() < 1e-12);
    }
}

//! Minimal benchmarking harness (criterion is not in the offline
//! dependency set).
//!
//! `cargo bench` targets use [`run`] to time named workloads with
//! warmup + repeated measurement, print mean/min/max wall time, and
//! return the last result so benches can also print the paper table they
//! regenerate. Timings are wall-clock (the benches pin no cores; treat
//! small deltas accordingly).
//!
//! ## The committed bench trajectory
//!
//! Every bench target also appends a schema-versioned entry to a
//! trajectory file at the workspace root (`BENCH_sim.json`,
//! `BENCH_hotpaths.json`) — the repo's perf record PR-over-PR. The
//! schema lives here so every bench shares one shape and one validator:
//!
//! ```json
//! {
//!   "schema": "plantd-bench-trajectory",
//!   "version": 1,
//!   "bench": "sim_campaign",
//!   "entries": [
//!     { "label": "pr6-indexheap", "unix_s": 1786147200,
//!       "host": "reference",
//!       "metrics": { "events_per_s": 1.6e7, "cells_per_s": 11.0 } }
//!   ]
//! }
//! ```
//!
//! [`append_entry`] validates the entry *and* the resulting document
//! before writing — a malformed entry is an error, never a silent
//! append — and resolves the destination via [`workspace_root`], not
//! the invocation cwd. `tests/bench_schema.rs` holds the committed
//! files to the same validator. See `docs/PERF.md` for reading and
//! update etiquette.

use std::path::{Path, PathBuf};
use std::time::Instant;

use super::json::Json;

/// One timed workload.
pub struct BenchResult {
    /// Workload label.
    pub name: String,
    /// Measured iterations (excluding warmup).
    pub iters: u32,
    /// Mean wall time per iteration, seconds.
    pub mean_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
    /// Slowest iteration, seconds.
    pub max_s: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "bench {:<40} {:>5} iters  mean {:>10}  min {:>10}  max {:>10}",
            self.name,
            self.iters,
            humane(self.mean_s),
            humane(self.min_s),
            humane(self.max_s)
        )
    }
}

fn humane(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
/// Returns the stats and the last iteration's output.
pub fn run<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> (BenchResult, T) {
    assert!(iters >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        times.push(t0.elapsed().as_secs_f64());
        last = Some(out);
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        min_s: times.iter().cloned().fold(f64::MAX, f64::min),
        max_s: times.iter().cloned().fold(f64::MIN, f64::max),
    };
    println!("{}", result.report());
    (result, last.unwrap())
}

/// Throughput helper: items processed per second at the mean time.
pub fn throughput(items: u64, r: &BenchResult) -> f64 {
    items as f64 / r.mean_s
}

// ---- the shared bench-trajectory schema ------------------------------------

/// Schema identifier every trajectory file must carry.
pub const TRAJECTORY_SCHEMA: &str = "plantd-bench-trajectory";

/// Current schema version. Readers reject newer versions (they cannot
/// know the shape); older files are upgraded by hand when the schema
/// moves, so there is no silent migration path.
pub const TRAJECTORY_VERSION: u64 = 1;

/// The canonical directory for `BENCH_*.json`: the workspace root
/// (parent of `rust/`), resolved from the crate's own manifest path so
/// it does not depend on the invocation cwd. `PLANTD_BENCH_DIR`
/// overrides for tests and sandboxed CI runs.
pub fn workspace_root() -> PathBuf {
    if let Ok(dir) = std::env::var("PLANTD_BENCH_DIR") {
        return PathBuf::from(dir);
    }
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("crate lives one level below the workspace root")
        .to_path_buf()
}

/// `workspace_root()/file` — where a trajectory named `file` lives.
pub fn trajectory_path(file: &str) -> PathBuf {
    workspace_root().join(file)
}

/// A fresh, empty trajectory document for `bench`.
pub fn new_trajectory(bench: &str) -> Json {
    Json::obj(vec![
        ("schema", Json::str(TRAJECTORY_SCHEMA)),
        ("version", Json::num(TRAJECTORY_VERSION as f64)),
        ("bench", Json::str(bench)),
        ("entries", Json::arr(Vec::new())),
    ])
}

/// Build one trajectory entry. `metrics` must be non-empty; rates use
/// names ending `_per_s` (the validator requires those to be positive).
pub fn entry(label: &str, unix_s: u64, host: &str, metrics: Vec<(&str, f64)>) -> Json {
    Json::obj(vec![
        ("label", Json::str(label)),
        ("unix_s", Json::num(unix_s as f64)),
        ("host", Json::str(host)),
        ("metrics", Json::obj(metrics.into_iter().map(|(k, v)| (k, Json::num(v))).collect())),
    ])
}

/// Validate one trajectory entry. Rules: non-empty `label` and `host`,
/// positive integral `unix_s`, and a non-empty `metrics` object whose
/// values are finite and non-negative — with every `*_per_s` rate
/// strictly positive (a zero rate means the bench measured nothing).
pub fn validate_entry(e: &Json) -> Result<(), String> {
    let label = e
        .get_str("label")
        .filter(|l| !l.is_empty())
        .ok_or("entry missing non-empty 'label'")?;
    let ctx = |msg: &str| format!("entry '{label}': {msg}");
    match e.get_u64("unix_s") {
        Some(t) if t > 0 => {}
        _ => return Err(ctx("'unix_s' must be a positive integer")),
    }
    if e.get_str("host").filter(|h| !h.is_empty()).is_none() {
        return Err(ctx("missing non-empty 'host'"));
    }
    let metrics = e
        .get("metrics")
        .and_then(Json::as_obj)
        .ok_or_else(|| ctx("missing 'metrics' object"))?;
    if metrics.is_empty() {
        return Err(ctx("'metrics' must not be empty"));
    }
    for (name, value) in metrics {
        if name.is_empty() {
            return Err(ctx("metric names must be non-empty"));
        }
        let v = value
            .as_f64()
            .ok_or_else(|| ctx(&format!("metric '{name}' is not a number")))?;
        if !v.is_finite() || v < 0.0 {
            return Err(ctx(&format!("metric '{name}' = {v} (must be finite, >= 0)")));
        }
        if name.ends_with("_per_s") && v <= 0.0 {
            return Err(ctx(&format!("rate '{name}' = {v} (rates must be > 0)")));
        }
    }
    Ok(())
}

/// Validate a whole trajectory document: schema id, supported version,
/// non-empty `bench` name, and every entry via [`validate_entry`].
pub fn validate_trajectory(doc: &Json) -> Result<(), String> {
    if doc.get_str("schema") != Some(TRAJECTORY_SCHEMA) {
        return Err(format!("'schema' must be \"{TRAJECTORY_SCHEMA}\""));
    }
    match doc.get_u64("version") {
        Some(v) if v == TRAJECTORY_VERSION => {}
        Some(v) => {
            return Err(format!(
                "unsupported trajectory version {v} (this build reads {TRAJECTORY_VERSION})"
            ))
        }
        None => return Err("'version' must be an integer".to_string()),
    }
    if doc.get_str("bench").filter(|b| !b.is_empty()).is_none() {
        return Err("missing non-empty 'bench'".to_string());
    }
    let entries = doc
        .get("entries")
        .and_then(Json::as_arr)
        .ok_or("missing 'entries' array")?;
    for (i, e) in entries.iter().enumerate() {
        validate_entry(e).map_err(|msg| format!("entries[{i}]: {msg}"))?;
    }
    Ok(())
}

/// Append a validated entry to the trajectory at `path`, creating the
/// file (as a fresh `bench` document) if absent. The entry, the
/// existing document, and the final document are all validated —
/// malformed input is an error and the file is left untouched.
pub fn append_entry(path: &Path, bench: &str, new: Json) -> Result<(), String> {
    validate_entry(&new).map_err(|e| format!("refusing to append: {e}"))?;
    let mut doc = match std::fs::read_to_string(path) {
        Ok(text) => {
            let doc = Json::parse(&text)
                .map_err(|e| format!("{}: {e}", path.display()))?;
            validate_trajectory(&doc)
                .map_err(|e| format!("{}: existing trajectory invalid: {e}", path.display()))?;
            if doc.get_str("bench") != Some(bench) {
                return Err(format!(
                    "{}: trajectory belongs to bench '{}', not '{bench}'",
                    path.display(),
                    doc.get_str("bench").unwrap_or("?")
                ));
            }
            doc
        }
        Err(e) if e.kind() == std::io::ErrorKind::NotFound => new_trajectory(bench),
        Err(e) => return Err(format!("{}: {e}", path.display())),
    };
    if let Json::Obj(map) = &mut doc {
        match map.get_mut("entries") {
            Some(Json::Arr(entries)) => entries.push(new),
            _ => return Err("trajectory 'entries' is not an array".to_string()),
        }
    }
    validate_trajectory(&doc)?;
    std::fs::write(path, doc.to_string_pretty())
        .map_err(|e| format!("{}: {e}", path.display()))?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_and_returns_output() {
        let (r, out) = run("noop-sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(out, 499_500);
        assert_eq!(r.iters, 5);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
        assert!(r.mean_s >= 0.0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            min_s: 0.5,
            max_s: 0.5,
        };
        assert_eq!(throughput(100, &r), 200.0);
    }

    #[test]
    fn humane_units() {
        assert_eq!(humane(2.0), "2.00s");
        assert_eq!(humane(0.002), "2.00ms");
        assert_eq!(humane(0.0000005), "0.5µs");
    }

    fn good_entry() -> Json {
        entry(
            "pr6-test",
            1_754_611_200,
            "reference",
            vec![("events_per_s", 1.5e7), ("p99_ns", 120.0)],
        )
    }

    #[test]
    fn fresh_trajectory_with_entry_validates() {
        let mut doc = new_trajectory("sim_campaign");
        validate_trajectory(&doc).unwrap();
        if let Json::Obj(map) = &mut doc {
            if let Some(Json::Arr(entries)) = map.get_mut("entries") {
                entries.push(good_entry());
            }
        }
        validate_trajectory(&doc).unwrap();
        // round-trips through the serializer
        let reparsed = Json::parse(&doc.to_string_pretty()).unwrap();
        validate_trajectory(&reparsed).unwrap();
    }

    #[test]
    fn malformed_entries_are_rejected_with_reasons() {
        // zero rate
        let e = entry("x", 1, "h", vec![("events_per_s", 0.0)]);
        assert!(validate_entry(&e).unwrap_err().contains("rates must be > 0"));
        // non-finite metric
        let e = entry("x", 1, "h", vec![("p50_ns", f64::NAN)]);
        assert!(validate_entry(&e).unwrap_err().contains("finite"));
        // negative metric
        let e = entry("x", 1, "h", vec![("p50_ns", -1.0)]);
        assert!(validate_entry(&e).is_err());
        // empty metrics
        let e = entry("x", 1, "h", vec![]);
        assert!(validate_entry(&e).unwrap_err().contains("must not be empty"));
        // missing label / host / time
        assert!(validate_entry(&entry("", 1, "h", vec![("a", 1.0)])).is_err());
        assert!(validate_entry(&entry("x", 0, "h", vec![("a", 1.0)])).is_err());
        assert!(validate_entry(&entry("x", 1, "", vec![("a", 1.0)])).is_err());
    }

    #[test]
    fn trajectory_rejects_wrong_schema_and_future_version() {
        let doc = Json::obj(vec![
            ("schema", Json::str("something-else")),
            ("version", Json::num(1.0)),
            ("bench", Json::str("b")),
            ("entries", Json::arr(vec![])),
        ]);
        assert!(validate_trajectory(&doc).unwrap_err().contains("schema"));
        let doc = Json::obj(vec![
            ("schema", Json::str(TRAJECTORY_SCHEMA)),
            ("version", Json::num(99.0)),
            ("bench", Json::str("b")),
            ("entries", Json::arr(vec![])),
        ]);
        assert!(validate_trajectory(&doc).unwrap_err().contains("version 99"));
        // a bad entry inside is located by index
        let mut doc = new_trajectory("b");
        if let Json::Obj(map) = &mut doc {
            if let Some(Json::Arr(entries)) = map.get_mut("entries") {
                entries.push(Json::obj(vec![("label", Json::str("broken"))]));
            }
        }
        assert!(validate_trajectory(&doc).unwrap_err().contains("entries[0]"));
    }

    #[test]
    fn append_entry_creates_validates_and_refuses_malformed() {
        let dir = std::env::temp_dir().join(format!("plantd-bench-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("BENCH_test.json");
        let _ = std::fs::remove_file(&path);

        append_entry(&path, "testbench", good_entry()).unwrap();
        append_entry(&path, "testbench", good_entry()).unwrap();
        let doc = Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        validate_trajectory(&doc).unwrap();
        assert_eq!(doc.get("entries").unwrap().as_arr().unwrap().len(), 2);

        // malformed entry: refused, file untouched
        let before = std::fs::read_to_string(&path).unwrap();
        let bad = entry("bad", 1, "h", vec![("events_per_s", 0.0)]);
        assert!(append_entry(&path, "testbench", bad).is_err());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), before);

        // wrong bench name: refused
        assert!(append_entry(&path, "otherbench", good_entry()).is_err());

        // corrupt existing file: refused, not clobbered
        std::fs::write(&path, "{not json").unwrap();
        assert!(append_entry(&path, "testbench", good_entry()).is_err());
        assert_eq!(std::fs::read_to_string(&path).unwrap(), "{not json");
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn workspace_root_is_the_repo_root_or_the_override() {
        // without the override, the root is the parent of rust/ — the
        // directory that holds Cargo.toml's workspace and tests/golden
        if std::env::var("PLANTD_BENCH_DIR").is_err() {
            let root = workspace_root();
            assert!(root.join("rust").is_dir(), "{}", root.display());
        }
        assert!(trajectory_path("BENCH_sim.json").ends_with("BENCH_sim.json"));
    }
}

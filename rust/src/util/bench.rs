//! Minimal benchmarking harness (criterion is not in the offline
//! dependency set).
//!
//! `cargo bench` targets use [`Bench`] to time named workloads with
//! warmup + repeated measurement, print mean/min/max wall time, and
//! return the last result so benches can also print the paper table they
//! regenerate. Timings are wall-clock (the benches pin no cores; treat
//! small deltas accordingly).

use std::time::Instant;

/// One timed workload.
pub struct BenchResult {
    /// Workload label.
    pub name: String,
    /// Measured iterations (excluding warmup).
    pub iters: u32,
    /// Mean wall time per iteration, seconds.
    pub mean_s: f64,
    /// Fastest iteration, seconds.
    pub min_s: f64,
    /// Slowest iteration, seconds.
    pub max_s: f64,
}

impl BenchResult {
    /// One-line human-readable summary.
    pub fn report(&self) -> String {
        format!(
            "bench {:<40} {:>5} iters  mean {:>10}  min {:>10}  max {:>10}",
            self.name,
            self.iters,
            humane(self.mean_s),
            humane(self.min_s),
            humane(self.max_s)
        )
    }
}

fn humane(s: f64) -> String {
    if s >= 1.0 {
        format!("{s:.2}s")
    } else if s >= 1e-3 {
        format!("{:.2}ms", s * 1e3)
    } else {
        format!("{:.1}µs", s * 1e6)
    }
}

/// Time `f` for `iters` iterations after `warmup` unmeasured runs.
/// Returns the stats and the last iteration's output.
pub fn run<T>(name: &str, warmup: u32, iters: u32, mut f: impl FnMut() -> T) -> (BenchResult, T) {
    assert!(iters >= 1);
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut times = Vec::with_capacity(iters as usize);
    let mut last = None;
    for _ in 0..iters {
        let t0 = Instant::now();
        let out = f();
        times.push(t0.elapsed().as_secs_f64());
        last = Some(out);
    }
    let result = BenchResult {
        name: name.to_string(),
        iters,
        mean_s: times.iter().sum::<f64>() / times.len() as f64,
        min_s: times.iter().cloned().fold(f64::MAX, f64::min),
        max_s: times.iter().cloned().fold(f64::MIN, f64::max),
    };
    println!("{}", result.report());
    (result, last.unwrap())
}

/// Throughput helper: items processed per second at the mean time.
pub fn throughput(items: u64, r: &BenchResult) -> f64 {
    items as f64 / r.mean_s
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn times_and_returns_output() {
        let (r, out) = run("noop-sum", 1, 5, || (0..1000u64).sum::<u64>());
        assert_eq!(out, 499_500);
        assert_eq!(r.iters, 5);
        assert!(r.min_s <= r.mean_s && r.mean_s <= r.max_s);
        assert!(r.mean_s >= 0.0);
    }

    #[test]
    fn throughput_math() {
        let r = BenchResult {
            name: "x".into(),
            iters: 1,
            mean_s: 0.5,
            min_s: 0.5,
            max_s: 0.5,
        };
        assert_eq!(throughput(100, &r), 200.0);
    }

    #[test]
    fn humane_units() {
        assert_eq!(humane(2.0), "2.00s");
        assert_eq!(humane(0.002), "2.00ms");
        assert_eq!(humane(0.0000005), "0.5µs");
    }
}

//! Tiny command-line parser for the `plantd` binary (clap is not in the
//! offline dependency set).
//!
//! Grammar: `plantd <subcommand> [--flag] [--key value]... [-k value]...
//! [positional]...` — a single-dash token whose first character is a
//! letter (`-f`) is a short option and stores under the dash-less name,
//! so `apply -f manifest.json` reads back as `opt("f")`. A single-dash
//! token that is not letter-led (`-0.5`) stays a value/positional.

use std::collections::BTreeMap;

/// Parsed command line: subcommand, options, flags, positionals.
#[derive(Debug, Clone, Default)]
pub struct Args {
    /// First bare word, e.g. `plantd simulate` → `Some("simulate")`.
    pub subcommand: Option<String>,
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    /// Bare words after the subcommand.
    pub positional: Vec<String>,
}

/// The `plantd` CLI's value-less flags. A generic `--name value` grammar
/// cannot tell a flag from an option, so names listed here never consume
/// the following token — `plantd get --check experiment` keeps `--check`
/// a flag and `experiment` a positional.
pub const BOOL_FLAGS: &[&str] = &[
    "all",
    "check",
    "dry-run",
    "native",
    "paper-twins",
    "update",
];

impl Args {
    /// Parse from an iterator of argument strings (not including argv[0]).
    /// Every `--name`/`-n` with a following non-option token is treated
    /// as an option with a value; see [`Args::parse_with_bool_flags`] for
    /// the variant that knows which names are value-less.
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Args, String> {
        Self::parse_with_bool_flags(args, &[])
    }

    /// [`Args::parse`], but names in `bool_flags` are always flags and
    /// never swallow the next token as a value.
    pub fn parse_with_bool_flags<I: IntoIterator<Item = String>>(
        args: I,
        bool_flags: &[&str],
    ) -> Result<Args, String> {
        let mut out = Args::default();
        let mut it = args.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(name) = a.strip_prefix("--") {
                if name.is_empty() {
                    return Err("bare '--' not supported".into());
                }
                if let Some((k, v)) = name.split_once('=') {
                    out.opts.insert(k.to_string(), v.to_string());
                } else if !bool_flags.contains(&name)
                    && it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(name.to_string(), v);
                } else {
                    out.flags.push(name.to_string());
                }
            } else if a.len() > 1
                && a.starts_with('-')
                && a.as_bytes()[1].is_ascii_alphabetic()
            {
                // short option: `-f value` (or a bare `-v` flag)
                let name = a[1..].to_string();
                if !bool_flags.contains(&name.as_str())
                    && it
                        .peek()
                        .map(|n| !n.starts_with("--"))
                        .unwrap_or(false)
                {
                    let v = it.next().unwrap();
                    out.opts.insert(name, v);
                } else {
                    out.flags.push(name);
                }
            } else if out.subcommand.is_none() && out.positional.is_empty() {
                out.subcommand = Some(a);
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    /// Parse the process arguments (skipping argv\[0\]), with
    /// [`BOOL_FLAGS`] treated as value-less.
    pub fn from_env() -> Result<Args, String> {
        Args::parse_with_bool_flags(std::env::args().skip(1), BOOL_FLAGS)
    }

    /// Whether a value-less `--name` flag was given.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Value of `--name value` (or `--name=value`), if present.
    pub fn opt(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    /// Option value with a default.
    pub fn opt_or(&self, name: &str, default: &str) -> String {
        self.opt(name).unwrap_or(default).to_string()
    }

    /// Option parsed as a float, with a default when absent.
    pub fn opt_f64(&self, name: &str, default: f64) -> Result<f64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected a number, got '{v}'")),
        }
    }

    /// Option parsed as an unsigned integer, with a default when absent.
    pub fn opt_u64(&self, name: &str, default: u64) -> Result<u64, String> {
        match self.opt(name) {
            None => Ok(default),
            Some(v) => v
                .parse()
                .map_err(|_| format!("--{name}: expected an integer, got '{v}'")),
        }
    }

    /// Error if any option/flag is not in the allowed set (typo guard).
    pub fn check_known(&self, allowed: &[&str]) -> Result<(), String> {
        for k in self.opts.keys().chain(self.flags.iter()) {
            if !allowed.contains(&k.as_str()) {
                return Err(format!(
                    "unknown option --{k}; expected one of: {}",
                    allowed.join(", ")
                ));
            }
        }
        Ok(())
    }
}

/// Parse an unsigned integer in decimal or `0x`-prefixed hex — the format
/// campaign reports print their replay seeds in, so a printed seed can be
/// passed straight back on the command line.
pub fn parse_seed(s: &str) -> Option<u64> {
    match s.strip_prefix("0x").or_else(|| s.strip_prefix("0X")) {
        Some(hex) => u64::from_str_radix(hex, 16).ok(),
        None => s.parse().ok(),
    }
}

/// Read a seed from a JSON value: either a string in [`parse_seed`] form
/// (`"0xD5"`, `"213"`) or a plain number. Strings carry the full u64
/// range; JSON numbers are f64 and lose precision above 2^53, so
/// manifests (and the specs that serialize to them) use the string form.
pub fn seed_from_json(v: &crate::util::json::Json) -> Option<u64> {
    match v.as_str() {
        Some(s) => parse_seed(s),
        None => v.as_u64(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(args: &[&str]) -> Args {
        Args::parse(args.iter().map(|s| s.to_string())).unwrap()
    }

    #[test]
    fn subcommand_and_options() {
        let a = parse(&["simulate", "--twin", "blocking", "--out", "out/"]);
        assert_eq!(a.subcommand.as_deref(), Some("simulate"));
        assert_eq!(a.opt("twin"), Some("blocking"));
        assert_eq!(a.opt("out"), Some("out/"));
    }

    #[test]
    fn equals_form() {
        let a = parse(&["x", "--rate=3.5"]);
        assert_eq!(a.opt_f64("rate", 0.0).unwrap(), 3.5);
    }

    #[test]
    fn flags_without_values() {
        let a = parse(&["run", "--verbose", "--seed", "7"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.opt_u64("seed", 0).unwrap(), 7);
    }

    #[test]
    fn trailing_flag() {
        let a = parse(&["run", "--fast"]);
        assert!(a.flag("fast"));
    }

    #[test]
    fn positionals() {
        let a = parse(&["report", "exp1", "exp2"]);
        assert_eq!(a.positional, vec!["exp1", "exp2"]);
    }

    #[test]
    fn numeric_parse_errors() {
        let a = parse(&["x", "--rate", "abc"]);
        assert!(a.opt_f64("rate", 0.0).is_err());
    }

    #[test]
    fn defaults() {
        let a = parse(&["x"]);
        assert_eq!(a.opt_or("missing", "d"), "d");
        assert_eq!(a.opt_f64("missing", 1.5).unwrap(), 1.5);
    }

    #[test]
    fn unknown_option_detected() {
        let a = parse(&["x", "--bogus", "1"]);
        assert!(a.check_known(&["rate"]).is_err());
        assert!(a.check_known(&["bogus"]).is_ok());
    }

    #[test]
    fn seed_parses_decimal_and_hex() {
        assert_eq!(parse_seed("213"), Some(213));
        assert_eq!(parse_seed("0xD5"), Some(0xD5));
        assert_eq!(parse_seed("0Xd5"), Some(0xD5));
        assert_eq!(parse_seed("0x"), None);
        assert_eq!(parse_seed("nope"), None);
        assert_eq!(parse_seed(""), None);
    }

    #[test]
    fn seed_from_json_handles_numbers_and_full_u64_strings() {
        use crate::util::json::Json;
        assert_eq!(seed_from_json(&Json::num(213)), Some(213));
        assert_eq!(seed_from_json(&Json::str("213")), Some(213));
        assert_eq!(seed_from_json(&Json::str("0xD5")), Some(0xD5));
        // the whole point: u64 seeds above 2^53 survive the string form
        assert_eq!(
            seed_from_json(&Json::str("0xDEADBEEFDEADBEEF")),
            Some(0xDEAD_BEEF_DEAD_BEEF)
        );
        assert_eq!(seed_from_json(&Json::str("junk")), None);
        assert_eq!(seed_from_json(&Json::Null), None);
    }

    #[test]
    fn negative_number_as_value() {
        // a value starting with '-' but not '--' is still a value
        let a = parse(&["x", "--growth", "-0.5"]);
        assert_eq!(a.opt_f64("growth", 0.0).unwrap(), -0.5);
    }

    #[test]
    fn short_option_with_value() {
        let a = parse(&["apply", "-f", "examples/manifests/windtunnel.json"]);
        assert_eq!(a.subcommand.as_deref(), Some("apply"));
        assert_eq!(a.opt("f"), Some("examples/manifests/windtunnel.json"));
        assert!(a.positional.is_empty());
    }

    #[test]
    fn short_flag_without_value() {
        let a = parse(&["x", "-v"]);
        assert!(a.flag("v"));
    }

    #[test]
    fn bare_negative_number_stays_positional() {
        let a = parse(&["x", "-0.5"]);
        assert_eq!(a.positional, vec!["-0.5"]);
        assert!(!a.flag("0.5"));
    }

    #[test]
    fn bool_flags_never_swallow_positionals() {
        let args = ["get", "--check", "experiment"].map(String::from);
        let a = Args::parse_with_bool_flags(args, BOOL_FLAGS).unwrap();
        assert!(a.flag("check"), "--check must stay a flag");
        assert_eq!(a.positional, vec!["experiment"]);
        let args = ["run", "--all", "out"].map(String::from);
        let a = Args::parse_with_bool_flags(args, BOOL_FLAGS).unwrap();
        assert!(a.flag("all"));
        assert_eq!(a.positional, vec!["out"]);
        // names NOT in the list still take values
        let args = ["run", "--out", "dir"].map(String::from);
        let a = Args::parse_with_bool_flags(args, BOOL_FLAGS).unwrap();
        assert_eq!(a.opt("out"), Some("dir"));
    }
}

//! Simulation time.
//!
//! Every PlantD component reads time through a [`Clock`] so that wind-tunnel
//! experiments can run on a *scaled* clock: the paper's 1230-second
//! blocking-write experiment replays in ~20 s of wall time at `scale = 60`,
//! while all reported timestamps, durations, throughputs and costs stay in
//! virtual (paper-unit) seconds. The scale is applied uniformly — to the
//! load generator's pacing, every stage's service time, and the metric
//! timestamps — so relative behaviour is preserved (DESIGN.md §5).

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Virtual time source. `now_s` returns seconds since the clock's epoch.
pub trait Clock: Send + Sync {
    /// Current virtual time, seconds since epoch.
    fn now_s(&self) -> f64;
    /// Block the calling thread for `sim_seconds` of virtual time.
    fn sleep_s(&self, sim_seconds: f64);
    /// Like `sleep_s` but without the precision spin — for background
    /// work (upload pools, persistence) whose exact wake time doesn't
    /// feed a measurement. Burns no CPU, so it cannot distort the
    /// foreground stages' timed service on a shared core.
    fn sleep_coarse_s(&self, sim_seconds: f64) {
        self.sleep_s(sim_seconds);
    }
    /// Virtual-to-wall scale factor (virtual seconds per wall second).
    fn scale(&self) -> f64 {
        1.0
    }
}

/// Shared handle to a [`Clock`] (every component holds one).
pub type SharedClock = Arc<dyn Clock>;

/// Wall clock with a virtual speed-up factor.
pub struct ScaledClock {
    origin: Instant,
    scale: f64,
}

impl ScaledClock {
    /// `scale` = virtual seconds per wall-clock second (≥ 1 speeds up).
    pub fn new(scale: f64) -> Arc<Self> {
        assert!(scale > 0.0, "clock scale must be positive");
        Arc::new(ScaledClock {
            origin: Instant::now(),
            scale,
        })
    }

    /// Unscaled wall clock (scale 1).
    pub fn realtime() -> Arc<Self> {
        Self::new(1.0)
    }
}

impl Clock for ScaledClock {
    fn now_s(&self) -> f64 {
        self.origin.elapsed().as_secs_f64() * self.scale
    }

    fn sleep_s(&self, sim_seconds: f64) {
        if sim_seconds <= 0.0 {
            return;
        }
        let wall = sim_seconds / self.scale;
        // Hybrid sleep: OS sleep overshoots by a scheduling quantum
        // (~60–500 µs), which at high clock scales would inflate every
        // modeled service time and corrupt measured throughput. Sleep for
        // the bulk, then yield-spin the final stretch for µs precision.
        const SPIN_S: f64 = 0.0005;
        let deadline = Instant::now() + Duration::from_secs_f64(wall);
        if wall > SPIN_S {
            std::thread::sleep(Duration::from_secs_f64(wall - SPIN_S));
        }
        while Instant::now() < deadline {
            std::thread::yield_now();
        }
    }

    fn sleep_coarse_s(&self, sim_seconds: f64) {
        if sim_seconds <= 0.0 {
            return;
        }
        std::thread::sleep(Duration::from_secs_f64(sim_seconds / self.scale));
    }

    fn scale(&self) -> f64 {
        self.scale
    }
}

/// Manually advanced clock for deterministic unit tests. `sleep_s` advances
/// the clock itself (single-threaded semantics).
pub struct ManualClock {
    nanos: AtomicU64,
}

impl ManualClock {
    /// Clock starting at virtual time 0.
    pub fn new() -> Arc<Self> {
        Arc::new(ManualClock {
            nanos: AtomicU64::new(0),
        })
    }

    /// Advance the clock by `seconds`.
    pub fn advance_s(&self, seconds: f64) {
        assert!(seconds >= 0.0);
        self.nanos
            .fetch_add((seconds * 1e9) as u64, Ordering::SeqCst);
    }

    /// Jump the clock to an absolute virtual time.
    pub fn set_s(&self, seconds: f64) {
        self.nanos.store((seconds * 1e9) as u64, Ordering::SeqCst);
    }
}

impl Clock for ManualClock {
    fn now_s(&self) -> f64 {
        self.nanos.load(Ordering::SeqCst) as f64 / 1e9
    }

    fn sleep_s(&self, sim_seconds: f64) {
        self.advance_s(sim_seconds.max(0.0));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manual_clock_advances() {
        let c = ManualClock::new();
        assert_eq!(c.now_s(), 0.0);
        c.advance_s(1.5);
        assert!((c.now_s() - 1.5).abs() < 1e-9);
        c.sleep_s(0.5);
        assert!((c.now_s() - 2.0).abs() < 1e-9);
    }

    #[test]
    fn manual_clock_set() {
        let c = ManualClock::new();
        c.set_s(100.0);
        assert!((c.now_s() - 100.0).abs() < 1e-9);
    }

    #[test]
    fn scaled_clock_runs_fast() {
        let c = ScaledClock::new(1000.0);
        let t0 = c.now_s();
        std::thread::sleep(Duration::from_millis(5));
        let dt = c.now_s() - t0;
        assert!(dt >= 4.0, "expected >= 4 virtual seconds, got {dt}");
    }

    #[test]
    fn scaled_sleep_divides_wall_time() {
        let c = ScaledClock::new(100.0);
        let w0 = Instant::now();
        c.sleep_s(1.0); // should sleep ~10 ms of wall time
        let wall = w0.elapsed().as_secs_f64();
        assert!(wall < 0.5, "slept {wall}s wall for 1 virtual second");
    }

    #[test]
    fn negative_sleep_is_noop() {
        let c = ScaledClock::new(1.0);
        let w0 = Instant::now();
        c.sleep_s(-5.0);
        assert!(w0.elapsed().as_secs_f64() < 0.1);
    }

    #[test]
    #[should_panic(expected = "positive")]
    fn zero_scale_rejected() {
        ScaledClock::new(0.0);
    }
}

//! Minimal CSV writing/reading for figure series dumps (`out/fig*.csv`)
//! and dataset payload formatting. RFC 4180 quoting.

use std::io::{self, Write};

/// Write one CSV row, quoting fields that need it.
pub fn write_row<W: Write>(w: &mut W, fields: &[String]) -> io::Result<()> {
    for (i, f) in fields.iter().enumerate() {
        if i > 0 {
            w.write_all(b",")?;
        }
        if f.contains(',') || f.contains('"') || f.contains('\n') {
            w.write_all(b"\"")?;
            w.write_all(f.replace('"', "\"\"").as_bytes())?;
            w.write_all(b"\"")?;
        } else {
            w.write_all(f.as_bytes())?;
        }
    }
    w.write_all(b"\n")
}

/// A convenience builder that accumulates a CSV document in memory.
#[derive(Debug, Default)]
pub struct CsvDoc {
    buf: Vec<u8>,
}

impl CsvDoc {
    /// Document starting with a header row.
    pub fn new(header: &[&str]) -> Self {
        let mut doc = CsvDoc { buf: Vec::new() };
        doc.push_strs(header);
        doc
    }

    /// Append a row of string slices.
    pub fn push_strs(&mut self, fields: &[&str]) {
        let owned: Vec<String> = fields.iter().map(|s| s.to_string()).collect();
        write_row(&mut self.buf, &owned).expect("vec write");
    }

    /// Append a row of owned fields.
    pub fn push(&mut self, fields: Vec<String>) {
        write_row(&mut self.buf, &fields).expect("vec write");
    }

    /// Row of numeric values formatted with `prec` decimals.
    pub fn push_nums(&mut self, label: Option<&str>, values: &[f64], prec: usize) {
        let mut fields: Vec<String> = Vec::new();
        if let Some(l) = label {
            fields.push(l.to_string());
        }
        fields.extend(values.iter().map(|v| format!("{v:.prec$}")));
        self.push(fields);
    }

    /// The document bytes accumulated so far.
    pub fn as_bytes(&self) -> &[u8] {
        &self.buf
    }

    /// Write to disk, creating parent directories as needed.
    pub fn save(&self, path: &std::path::Path) -> io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        std::fs::write(path, &self.buf)
    }
}

/// Parse a CSV document into rows of fields (handles quoted fields).
pub fn parse(text: &str) -> Vec<Vec<String>> {
    let mut rows = Vec::new();
    let mut row: Vec<String> = Vec::new();
    let mut field = String::new();
    let mut chars = text.chars().peekable();
    let mut in_quotes = false;
    while let Some(c) = chars.next() {
        if in_quotes {
            match c {
                '"' => {
                    if chars.peek() == Some(&'"') {
                        chars.next();
                        field.push('"');
                    } else {
                        in_quotes = false;
                    }
                }
                c => field.push(c),
            }
        } else {
            match c {
                '"' => in_quotes = true,
                ',' => row.push(std::mem::take(&mut field)),
                '\n' => {
                    row.push(std::mem::take(&mut field));
                    rows.push(std::mem::take(&mut row));
                }
                '\r' => {}
                c => field.push(c),
            }
        }
    }
    if !field.is_empty() || !row.is_empty() {
        row.push(field);
        rows.push(row);
    }
    rows
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_plain() {
        let mut d = CsvDoc::new(&["a", "b"]);
        d.push_strs(&["1", "2"]);
        let rows = parse(std::str::from_utf8(d.as_bytes()).unwrap());
        assert_eq!(rows, vec![vec!["a", "b"], vec!["1", "2"]]);
    }

    #[test]
    fn quoting_commas_and_quotes() {
        let mut d = CsvDoc::new(&["x"]);
        d.push_strs(&["a,b"]);
        d.push_strs(&["say \"hi\""]);
        let text = String::from_utf8(d.as_bytes().to_vec()).unwrap();
        assert!(text.contains("\"a,b\""));
        let rows = parse(&text);
        assert_eq!(rows[1][0], "a,b");
        assert_eq!(rows[2][0], "say \"hi\"");
    }

    #[test]
    fn push_nums_precision() {
        let mut d = CsvDoc::new(&["h", "v"]);
        d.push_nums(Some("0"), &[1.23456], 2);
        let rows = parse(std::str::from_utf8(d.as_bytes()).unwrap());
        assert_eq!(rows[1], vec!["0", "1.23"]);
    }

    #[test]
    fn parse_crlf_and_trailing_newline() {
        let rows = parse("a,b\r\n1,2\r\n");
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[1], vec!["1", "2"]);
    }

    #[test]
    fn parse_embedded_newline_in_quotes() {
        let rows = parse("\"a\nb\",c\n");
        assert_eq!(rows[0][0], "a\nb");
        assert_eq!(rows[0][1], "c");
    }
}

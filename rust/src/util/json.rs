//! A small, complete JSON implementation (value model, parser, writer).
//!
//! PlantD uses JSON for resource specs (schemas, load patterns, traffic
//! models), the artifact manifest written by `python/compile/aot.py`, and
//! report output. `serde`/`serde_json` are not in the offline dependency
//! set, so this module implements RFC 8259 directly.

use std::collections::BTreeMap;
use std::fmt;

/// A JSON value. Objects use a `BTreeMap` so serialization is canonical
/// (sorted keys), which keeps report/golden-file diffs stable.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    /// `null`.
    Null,
    /// `true` / `false`.
    Bool(bool),
    /// Any JSON number (always held as f64).
    Num(f64),
    /// A string.
    Str(String),
    /// An ordered array.
    Arr(Vec<Json>),
    /// An object with canonically-sorted keys.
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset and message.
#[derive(Debug)]
pub struct ParseError {
    /// Byte offset into the input where parsing failed.
    pub offset: usize,
    /// Human-readable description of the failure.
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    // ---- constructors -----------------------------------------------------

    /// Object from `(key, value)` pairs.
    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(
            pairs
                .into_iter()
                .map(|(k, v)| (k.to_string(), v))
                .collect(),
        )
    }

    /// Array from any iterator of values.
    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    /// Number from anything convertible to f64.
    pub fn num<N: Into<f64>>(n: N) -> Json {
        Json::Num(n.into())
    }

    /// String value.
    pub fn str<S: Into<String>>(s: S) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors --------------------------------------------------------

    /// Numeric value, if this is a number.
    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Non-negative integral numeric value, if exactly representable.
    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().and_then(|f| {
            if f >= 0.0 && f.fract() == 0.0 && f <= u64::MAX as f64 {
                Some(f as u64)
            } else {
                None
            }
        })
    }

    /// String value, if this is a string.
    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    /// Boolean value, if this is a bool.
    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    /// Array elements, if this is an array.
    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Key→value map, if this is an object.
    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// Object field lookup; `None` for non-objects or missing keys.
    pub fn get(&self, key: &str) -> Option<&Json> {
        self.as_obj().and_then(|o| o.get(key))
    }

    /// Path lookup: `v.path(&["entry_points", "twin_sim", "file"])`.
    pub fn path(&self, keys: &[&str]) -> Option<&Json> {
        let mut cur = self;
        for k in keys {
            cur = cur.get(k)?;
        }
        Some(cur)
    }

    /// String field lookup: `get(key)` + [`Json::as_str`].
    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).and_then(Json::as_str)
    }

    /// Numeric field lookup: `get(key)` + [`Json::as_f64`].
    pub fn get_f64(&self, key: &str) -> Option<f64> {
        self.get(key).and_then(Json::as_f64)
    }

    /// Unsigned-integer field lookup: `get(key)` + [`Json::as_u64`].
    pub fn get_u64(&self, key: &str) -> Option<u64> {
        self.get(key).and_then(Json::as_u64)
    }

    // ---- serialization ----------------------------------------------------

    /// Compact serialization.
    pub fn to_string_compact(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        s
    }

    /// Pretty serialization with 2-space indent.
    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => write_num(out, *n),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                write_seq(out, indent, depth, '[', ']', items.len(), |out, i| {
                    items[i].write(out, indent, depth + 1)
                })
            }
            Json::Obj(map) => {
                let keys: Vec<&String> = map.keys().collect();
                write_seq(out, indent, depth, '{', '}', map.len(), |out, i| {
                    write_escaped(out, keys[i]);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    map[keys[i]].write(out, indent, depth + 1);
                })
            }
        }
    }

    // ---- parsing ----------------------------------------------------------

    /// Parse an RFC 8259 JSON document.
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser {
            bytes: text.as_bytes(),
            pos: 0,
        };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }
}

fn write_num(out: &mut String, n: f64) {
    if n.is_finite() {
        if n.fract() == 0.0 && n.abs() < 1e15 {
            fmt::Write::write_fmt(out, format_args!("{}", n as i64)).unwrap();
        } else {
            fmt::Write::write_fmt(out, format_args!("{n}")).unwrap();
        }
    } else {
        // JSON has no NaN/Inf; emit null like most encoders
        out.push_str("null");
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                fmt::Write::write_fmt(out, format_args!("\\u{:04x}", c as u32)).unwrap()
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn write_seq(
    out: &mut String,
    indent: Option<usize>,
    depth: usize,
    open: char,
    close: char,
    len: usize,
    mut item: impl FnMut(&mut String, usize),
) {
    out.push(open);
    for i in 0..len {
        if i > 0 {
            out.push(',');
        }
        if let Some(w) = indent {
            out.push('\n');
            out.push_str(&" ".repeat(w * (depth + 1)));
        }
        item(out, i);
    }
    if len > 0 {
        if let Some(w) = indent {
            out.push('\n');
            out.push_str(&" ".repeat(w * depth));
        }
    }
    out.push(close);
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError {
            offset: self.pos,
            msg: msg.to_string(),
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, b: u8) -> Result<(), ParseError> {
        if self.peek() == Some(b) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", b as char)))
        }
    }

    fn literal(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.literal("null", Json::Null),
            Some(b't') => self.literal("true", Json::Bool(true)),
            Some(b'f') => self.literal("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            Some(_) => Err(self.err("unexpected character")),
            None => Err(self.err("unexpected end of input")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(map));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => out.push('"'),
                        Some(b'\\') => out.push('\\'),
                        Some(b'/') => out.push('/'),
                        Some(b'b') => out.push('\u{8}'),
                        Some(b'f') => out.push('\u{c}'),
                        Some(b'n') => out.push('\n'),
                        Some(b'r') => out.push('\r'),
                        Some(b't') => out.push('\t'),
                        Some(b'u') => {
                            self.pos += 1;
                            let cp = self.hex4()?;
                            // handle surrogate pairs
                            let c = if (0xD800..0xDC00).contains(&cp) {
                                if self.bytes[self.pos..].starts_with(b"\\u") {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    let combined = 0x10000
                                        + ((cp - 0xD800) << 10)
                                        + (lo - 0xDC00);
                                    char::from_u32(combined)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(cp)
                            };
                            out.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 already advanced pos
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 char
                    let rest = &self.bytes[self.pos..];
                    let s = std::str::from_utf8(rest)
                        .map_err(|_| self.err("invalid utf-8"))?;
                    let c = s.chars().next().unwrap();
                    out.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, ParseError> {
        if self.pos + 4 > self.bytes.len() {
            return Err(self.err("truncated \\u escape"));
        }
        let s = std::str::from_utf8(&self.bytes[self.pos..self.pos + 4])
            .map_err(|_| self.err("bad hex"))?;
        let v = u32::from_str_radix(s, 16).map_err(|_| self.err("bad hex"))?;
        self.pos += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("bad number"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(v: &Json) {
        let s = v.to_string_compact();
        assert_eq!(&Json::parse(&s).unwrap(), v, "compact roundtrip of {s}");
        let p = v.to_string_pretty();
        assert_eq!(&Json::parse(&p).unwrap(), v, "pretty roundtrip of {p}");
    }

    #[test]
    fn scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::str("hi"));
    }

    #[test]
    fn nested_structure() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.path(&["a"]).unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(v.get("c").unwrap().as_str(), Some("x"));
        roundtrip(&v);
    }

    #[test]
    fn string_escapes() {
        let v = Json::parse(r#""a\n\t\"\\A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\A"));
        roundtrip(&v);
    }

    #[test]
    fn surrogate_pair() {
        let v = Json::parse(r#""😀""#).unwrap();
        assert_eq!(v.as_str(), Some("😀"));
    }

    #[test]
    fn unicode_passthrough() {
        let v = Json::Str("héllo — 世界".to_string());
        roundtrip(&v);
    }

    #[test]
    fn empty_containers() {
        roundtrip(&Json::Arr(vec![]));
        roundtrip(&Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(Json::parse(" { } ").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn whitespace_tolerance() {
        let v = Json::parse(" {\n\t\"a\" :\r [ 1 , 2 ] } ").unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 2);
    }

    #[test]
    fn errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("nul").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn error_offsets_point_at_problem() {
        let e = Json::parse("[1, x]").unwrap_err();
        assert_eq!(e.offset, 4);
    }

    #[test]
    fn integer_formatting_has_no_decimal_point() {
        assert_eq!(Json::num(3).to_string_compact(), "3");
        assert_eq!(Json::num(3.5).to_string_compact(), "3.5");
    }

    #[test]
    fn nan_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).to_string_compact(), "null");
    }

    #[test]
    fn as_u64_rejects_fractions_and_negatives() {
        assert_eq!(Json::num(3).as_u64(), Some(3));
        assert_eq!(Json::num(3.5).as_u64(), None);
        assert_eq!(Json::num(-1).as_u64(), None);
    }

    #[test]
    fn obj_keys_sorted_canonically() {
        let v = Json::obj(vec![("z", Json::num(1)), ("a", Json::num(2))]);
        assert_eq!(v.to_string_compact(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn parse_manifest_shape() {
        // mirror of what aot.py writes
        let text = r#"{"hours": 8760, "days": 365, "scenarios": 8,
            "entry_points": {"twin_sim": {"file": "twin_sim.hlo.txt",
            "inputs": [{"shape": [], "dtype": "float32"}]}}}"#;
        let v = Json::parse(text).unwrap();
        assert_eq!(v.get("hours").unwrap().as_u64(), Some(8760));
        assert_eq!(
            v.path(&["entry_points", "twin_sim", "file"]).unwrap().as_str(),
            Some("twin_sim.hlo.txt")
        );
    }
}

//! Minimal diagnostics logging (a `log`-crate stand-in).
//!
//! PlantD's library code must not chat on stderr from hot paths, and
//! repeated fallback warnings (one per call) drown real signal. This
//! module gives the two primitives the codebase needs: a uniformly
//! formatted [`warn`], and [`warn_once`] for per-process one-shot
//! warnings gated by a caller-owned [`Once`].

use std::sync::Once;

/// Emit a warning to stderr, uniformly prefixed.
pub fn warn(msg: &str) {
    eprintln!("warning: {msg}");
}

/// Emit a warning at most once per `gate` (typically a
/// `static Once`). Returns whether this call actually emitted, so
/// callers (and tests) can observe the dedup.
pub fn warn_once(gate: &Once, msg: &str) -> bool {
    let mut emitted = false;
    gate.call_once(|| {
        warn(msg);
        emitted = true;
    });
    emitted
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warn_once_emits_exactly_once_per_gate() {
        let gate = Once::new();
        assert!(warn_once(&gate, "first"));
        assert!(!warn_once(&gate, "second (suppressed)"));
        assert!(!warn_once(&gate, "third (suppressed)"));
        let other = Once::new();
        assert!(warn_once(&other, "different gate emits"));
    }
}

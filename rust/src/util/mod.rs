//! Cross-cutting utilities: deterministic PRNG, JSON, statistics, ASCII
//! tables, CSV, the scaled simulation clock, a tiny CLI parser, and a
//! property-testing helper.
//!
//! These stand in for crates (rand, serde, clap, proptest) that are not in
//! the offline dependency set — see DESIGN.md §5 (substitutions). They are
//! deliberately small, fully tested, and dependency-free.

pub mod bench;
pub mod cli;
pub mod clock;
pub mod csv;
pub mod json;
pub mod log;
pub mod proptest;
pub mod rng;
pub mod stats;
pub mod table;
pub mod units;

//! Minimal property-testing harness (the `proptest` crate is not in the
//! offline dependency set).
//!
//! `check(name, cases, |rng| ...)` runs a closure over `cases` independent
//! deterministic generators; a failure reports the case seed so it can be
//! replayed with `check_seed`. Used for coordinator invariants: routing,
//! batching, queue/state conservation, cost-allocation totals.

use super::rng::Rng;

/// Run `f` for `cases` generated cases. Panics (with the failing seed) on
/// the first case whose closure panics.
pub fn check<F: FnMut(&mut Rng)>(name: &str, cases: u32, mut f: F) {
    for case in 0..cases {
        let seed = derive_seed(name, case);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            let mut rng = Rng::new(seed);
            f(&mut rng);
        }));
        if let Err(payload) = result {
            let msg = payload
                .downcast_ref::<String>()
                .map(|s| s.as_str())
                .or_else(|| payload.downcast_ref::<&str>().copied())
                .unwrap_or("<non-string panic>");
            panic!(
                "property '{name}' failed on case {case} (replay: check_seed(\"{name}\", {seed:#x}, f)): {msg}"
            );
        }
    }
}

/// Replay a single failing case by seed.
pub fn check_seed<F: FnMut(&mut Rng)>(_name: &str, seed: u64, mut f: F) {
    let mut rng = Rng::new(seed);
    f(&mut rng);
}

fn derive_seed(name: &str, case: u32) -> u64 {
    // FNV-1a over the name, mixed with the case index
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h ^ ((case as u64) << 32 | case as u64)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passes_good_property() {
        check("abs-nonneg", 50, |rng| {
            let x = rng.normal(0.0, 10.0);
            assert!(x.abs() >= 0.0);
        });
    }

    #[test]
    fn reports_failing_seed() {
        let result = std::panic::catch_unwind(|| {
            check("always-fails", 3, |_| panic!("boom"));
        });
        let msg = match result {
            Err(p) => p
                .downcast_ref::<String>()
                .cloned()
                .unwrap_or_default(),
            Ok(_) => panic!("property should have failed"),
        };
        assert!(msg.contains("always-fails"), "{msg}");
        assert!(msg.contains("replay"), "{msg}");
        assert!(msg.contains("boom"), "{msg}");
    }

    #[test]
    fn seeds_differ_across_cases_and_names() {
        assert_ne!(derive_seed("a", 0), derive_seed("a", 1));
        assert_ne!(derive_seed("a", 0), derive_seed("b", 0));
    }

    #[test]
    fn replay_is_deterministic() {
        let seed = derive_seed("det", 4);
        let mut v1 = 0.0;
        let mut v2 = 1.0;
        check_seed("det", seed, |rng| v1 = rng.f64());
        check_seed("det", seed, |rng| v2 = rng.f64());
        assert_eq!(v1, v2);
    }
}

//! Deterministic pseudo-random number generation.
//!
//! PCG32 (O'Neill 2014) seeded through SplitMix64, plus the distribution
//! helpers the data/load generators need. Every randomized component of
//! PlantD (datagen fields, stage service jitter, property tests) takes an
//! explicit seed so experiments replay bit-identically.

/// PCG32 (XSH-RR 64/32) generator.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

/// SplitMix64 step — used to expand a user seed into PCG initial state.
fn splitmix64(x: &mut u64) -> u64 {
    *x = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *x;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Create a generator from a 64-bit seed.
    pub fn new(seed: u64) -> Self {
        let mut s = seed;
        let state = splitmix64(&mut s);
        let inc = splitmix64(&mut s) | 1;
        let mut rng = Rng { state, inc };
        rng.next_u32(); // decorrelate first output from the raw seed
        rng
    }

    /// Derive an independent child generator (e.g. one per stage/worker).
    pub fn fork(&mut self, stream: u64) -> Rng {
        Rng::new(self.next_u64() ^ stream.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next raw 32-bit output.
    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old
            .wrapping_mul(6_364_136_223_846_793_005)
            .wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    /// Next raw 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)`.
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`. `lo == hi` returns `lo`.
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[lo, hi]` (inclusive), Lemire-style rejection.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi, "int_range: lo {lo} > hi {hi}");
        let span = (hi - lo) as u64 + 1;
        if span == 0 {
            // full u64 range
            return self.next_u64() as i64;
        }
        // rejection sampling to remove modulo bias
        let zone = u64::MAX - (u64::MAX % span);
        loop {
            let v = self.next_u64();
            if v < zone {
                return lo + (v % span) as i64;
            }
        }
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self, mean: f64, std: f64) -> f64 {
        loop {
            let u1 = self.f64();
            if u1 > 1e-300 {
                let u2 = self.f64();
                let z = (-2.0 * u1.ln()).sqrt()
                    * (2.0 * std::f64::consts::PI * u2).cos();
                return mean + std * z;
            }
        }
    }

    /// Exponential with the given rate (events/unit-time).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        assert!(rate > 0.0);
        let u = 1.0 - self.f64(); // in (0, 1]
        -u.ln() / rate
    }

    /// Bernoulli trial.
    pub fn chance(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Pick a uniformly random element of a non-empty slice.
    pub fn choice<'a, T>(&mut self, items: &'a [T]) -> &'a T {
        assert!(!items.is_empty(), "choice on empty slice");
        &items[self.int_range(0, items.len() as i64 - 1) as usize]
    }

    /// Random alphanumeric string of the given length.
    pub fn alphanumeric(&mut self, len: usize) -> String {
        const CHARS: &[u8] = b"ABCDEFGHIJKLMNOPQRSTUVWXYZabcdefghijklmnopqrstuvwxyz0123456789";
        (0..len)
            .map(|_| *self.choice(CHARS) as char)
            .collect()
    }

    /// Fill a byte buffer with random data.
    pub fn fill_bytes(&mut self, buf: &mut [u8]) {
        for chunk in buf.chunks_mut(8) {
            let v = self.next_u64().to_le_bytes();
            chunk.copy_from_slice(&v[..chunk.len()]);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_replay() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(2);
        let same = (0..64).filter(|_| a.next_u32() == b.next_u32()).count();
        assert!(same < 4, "seeds 1/2 nearly collide: {same}");
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(7);
        let mut c1 = root.fork(1);
        let mut c2 = root.fork(2);
        let same = (0..64).filter(|_| c1.next_u32() == c2.next_u32()).count();
        assert!(same < 4);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let v = r.f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn f64_mean_near_half() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn int_range_inclusive_and_unbiased_ends() {
        let mut r = Rng::new(5);
        let mut lo_seen = false;
        let mut hi_seen = false;
        for _ in 0..10_000 {
            let v = r.int_range(-3, 3);
            assert!((-3..=3).contains(&v));
            lo_seen |= v == -3;
            hi_seen |= v == 3;
        }
        assert!(lo_seen && hi_seen);
    }

    #[test]
    fn int_range_single_point() {
        let mut r = Rng::new(6);
        assert_eq!(r.int_range(9, 9), 9);
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(8);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal(10.0, 2.0)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 10.0).abs() < 0.05, "mean {mean}");
        assert!((var - 4.0).abs() < 0.15, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(9);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.exponential(4.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.25).abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn chance_frequency() {
        let mut r = Rng::new(10);
        let hits = (0..100_000).filter(|_| r.chance(0.3)).count();
        assert!((hits as f64 / 1e5 - 0.3).abs() < 0.01);
    }

    #[test]
    fn alphanumeric_len_and_charset() {
        let mut r = Rng::new(11);
        let s = r.alphanumeric(64);
        assert_eq!(s.len(), 64);
        assert!(s.chars().all(|c| c.is_ascii_alphanumeric()));
    }

    #[test]
    fn fill_bytes_covers_partial_chunks() {
        let mut r = Rng::new(12);
        let mut buf = [0u8; 13];
        r.fill_bytes(&mut buf);
        assert!(buf.iter().any(|&b| b != 0));
    }
}

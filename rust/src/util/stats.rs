//! Descriptive statistics for metric series: streaming moments (Welford),
//! exact quantiles, and weighted quantiles (used for per-record latency
//! percentiles where each hour is weighted by its arrival count) — plus
//! the queueing-theory building blocks ([`erlang_b`], [`erlang_c`]) and
//! goodness-of-fit statistics ([`ks_statistic`],
//! [`chi_squared_statistic`]) the [`crate::validate`] oracle uses to
//! prove the sim kernel against closed-form ground truth.

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator (count 0; moments are NaN until pushed).
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample into the running moments.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples pushed.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    ///
    /// Welford's single pass accumulates `m2 = Σ(x − mean)²`, which is
    /// exactly 0 after one sample; by the same convention `variance`
    /// (and [`Welford::std`]) return **0.0 for n < 2** — a series with
    /// zero or one samples has no observed spread. Returning NaN here
    /// (the old behaviour) poisoned every downstream aggregate that
    /// folded an empty accumulator in.
    ///
    /// ```
    /// use plantd::util::stats::Welford;
    /// let mut w = Welford::new();
    /// assert_eq!(w.variance(), 0.0); // empty: no spread, not NaN
    /// w.push(3.0);
    /// assert_eq!((w.variance(), w.std()), (0.0, 0.0)); // single sample
    /// w.push(5.0);
    /// assert!((w.variance() - 1.0).abs() < 1e-12); // {3, 5}: σ² = 1
    /// ```
    pub fn variance(&self) -> f64 {
        if self.n < 2 {
            0.0
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation (0.0 for n < 2, like
    /// [`Welford::variance`]).
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample seen (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Largest sample seen (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

/// Exact quantile of a sample (linear interpolation between order
/// statistics, the "type 7" definition used by numpy's default).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0,1]");
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    quantile_sorted(&v, q)
}

/// Quantile of an already-sorted slice.
pub fn quantile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median (the 0.5 quantile) of a sample.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Arithmetic mean; NaN on an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Weighted quantile: the smallest value `v` such that the summed weight of
/// samples `<= v` reaches `q` of the total weight. Samples with weight
/// `<= 0` (including all-zero and NaN weights) are filtered out *before*
/// the total is formed, so the division by the total only ever happens
/// against a strictly positive sum — an all-zero (or empty) weight vector
/// returns NaN instead of dividing by zero. Used for per-record latency
/// stats where each simulated hour carries `arrivals(hour)` records.
pub fn weighted_quantile(values: &[f64], weights: &[f64], q: f64) -> f64 {
    assert_eq!(values.len(), weights.len());
    assert!((0.0..=1.0).contains(&q));
    let mut idx: Vec<usize> = (0..values.len()).filter(|&i| weights[i] > 0.0).collect();
    if idx.is_empty() {
        return f64::NAN;
    }
    idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).expect("NaN value"));
    let total: f64 = idx.iter().map(|&i| weights[i]).sum();
    let target = q * total;
    let mut acc = 0.0;
    for &i in &idx {
        acc += weights[i];
        if acc >= target {
            return values[i];
        }
    }
    values[*idx.last().unwrap()]
}

/// Weighted mean; NaN on zero total weight.
pub fn weighted_mean(values: &[f64], weights: &[f64]) -> f64 {
    assert_eq!(values.len(), weights.len());
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return f64::NAN;
    }
    values
        .iter()
        .zip(weights)
        .map(|(v, w)| v * w)
        .sum::<f64>()
        / total
}

/// Fraction of weight whose value satisfies `value <= limit`.
/// This is the paper's "% latency met" column.
pub fn weighted_fraction_below(values: &[f64], weights: &[f64], limit: f64) -> f64 {
    assert_eq!(values.len(), weights.len());
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return f64::NAN;
    }
    values
        .iter()
        .zip(weights)
        .filter(|(v, _)| **v <= limit)
        .map(|(_, w)| w)
        .sum::<f64>()
        / total
}

// ------------------------------------------------- queueing-theory blocks

/// Erlang-B blocking probability: the fraction of arrivals lost by an
/// M/M/c/c system (c servers, **no** waiting room) at offered load
/// `a = λ/μ` Erlangs. Computed with the standard numerically-stable
/// recurrence `B(0) = 1, B(k) = a·B(k−1) / (k + a·B(k−1))` — pure
/// rational arithmetic, so the result is bit-identical on every
/// IEEE-754 platform (the golden-snapshot harness relies on this).
pub fn erlang_b(servers: usize, a: f64) -> f64 {
    assert!(
        a >= 0.0 && a.is_finite(),
        "offered load must be finite and >= 0, got {a}"
    );
    let mut b = 1.0;
    for k in 1..=servers {
        b = a * b / (k as f64 + a * b);
    }
    b
}

/// Erlang-C probability that an arrival to an M/M/c queue (c servers,
/// unbounded waiting room) has to wait, at offered load `a = λ/μ`
/// Erlangs. Derived from [`erlang_b`] via
/// `C = c·B / (c − a·(1 − B))`. The formula requires `a < c` for a
/// stable queue; at or beyond saturation every arrival waits, so this
/// returns 1.0 for `a >= c`.
pub fn erlang_c(servers: usize, a: f64) -> f64 {
    assert!(servers >= 1, "erlang_c needs at least one server");
    if a <= 0.0 {
        return 0.0;
    }
    let c = servers as f64;
    if a >= c {
        return 1.0;
    }
    let b = erlang_b(servers, a);
    c * b / (c - a * (1.0 - b))
}

// --------------------------------------------------- goodness-of-fit stats

/// Two-sided Kolmogorov–Smirnov statistic of a sample against a
/// continuous CDF: `D = sup_x |F_n(x) − F(x)|`, evaluated exactly at
/// the order statistics (the supremum of the empirical-vs-continuous
/// gap is attained at a sample point, approaching from either side).
/// NaN on an empty sample.
pub fn ks_statistic<F: Fn(f64) -> f64>(xs: &[f64], cdf: F) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in ks_statistic input"));
    let n = v.len() as f64;
    let mut d = 0.0f64;
    for (i, x) in v.iter().enumerate() {
        let f = cdf(*x);
        d = d.max(((i + 1) as f64 / n - f).abs());
        d = d.max((f - i as f64 / n).abs());
    }
    d
}

/// Pearson chi-squared statistic `Σ (observed − expected)² / expected`
/// over parallel bin counts. Panics if any expected count is `<= 0`
/// (merge sparse bins before calling).
pub fn chi_squared_statistic(observed: &[f64], expected: &[f64]) -> f64 {
    assert_eq!(observed.len(), expected.len(), "bin count mismatch");
    observed
        .iter()
        .zip(expected)
        .map(|(o, e)| {
            assert!(*e > 0.0, "expected bin count must be > 0, got {e}");
            let d = o - e;
            d * d / e
        })
        .sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0_f64).powi(2)).sum::<f64>() / 5.0;
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_empty_mean_is_nan_but_spread_is_zero() {
        // moments that need at least one sample stay NaN; spread measures
        // are 0.0 below two samples (see the variance() docs)
        let w = Welford::new();
        assert!(w.mean().is_nan());
        assert!(w.min().is_nan());
        assert!(w.max().is_nan());
        assert_eq!(w.variance(), 0.0);
        assert_eq!(w.std(), 0.0);
    }

    #[test]
    fn welford_single_sample_has_zero_spread() {
        let mut w = Welford::new();
        w.push(42.0);
        assert_eq!(w.mean(), 42.0);
        assert_eq!(w.variance(), 0.0, "one sample: no observed spread");
        assert_eq!(w.std(), 0.0);
    }

    #[test]
    fn quantile_basics() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_single_element() {
        assert_eq!(quantile(&[7.0], 0.3), 7.0);
    }

    #[test]
    fn quantile_empty_nan() {
        assert!(quantile(&[], 0.5).is_nan());
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn weighted_quantile_skews_with_weight() {
        let values = [1.0, 2.0, 3.0];
        // almost all weight on 3.0
        assert_eq!(weighted_quantile(&values, &[0.01, 0.01, 100.0], 0.5), 3.0);
        // uniform weights: median is the middle value
        assert_eq!(weighted_quantile(&values, &[1.0, 1.0, 1.0], 0.5), 2.0);
    }

    #[test]
    fn weighted_quantile_ignores_zero_weight() {
        let v = [100.0, 1.0, 2.0];
        let w = [0.0, 1.0, 1.0];
        assert_eq!(weighted_quantile(&v, &w, 1.0), 2.0);
    }

    #[test]
    fn weighted_quantile_zero_total_weight_is_nan_not_div_by_zero() {
        // every weight filtered out: NaN, never a 0/0 division
        assert!(weighted_quantile(&[1.0, 2.0], &[0.0, 0.0], 0.5).is_nan());
        assert!(weighted_quantile(&[1.0, 2.0], &[-1.0, 0.0], 0.5).is_nan());
        assert!(weighted_quantile(&[], &[], 0.5).is_nan());
        // NaN weights are filtered like non-positive ones
        assert_eq!(
            weighted_quantile(&[7.0, 9.0], &[f64::NAN, 1.0], 0.5),
            9.0
        );
    }

    #[test]
    fn erlang_b_known_values() {
        // B(1, a) = a / (1 + a)
        assert!((erlang_b(1, 0.5) - 0.5 / 1.5).abs() < 1e-15);
        // classic table value: B(2, 1) = 0.2
        assert!((erlang_b(2, 1.0) - 0.2).abs() < 1e-15);
        // no servers: every arrival blocked; no load: never blocked
        assert_eq!(erlang_b(0, 1.0), 1.0);
        assert_eq!(erlang_b(4, 0.0), 0.0);
        // monotone decreasing in servers
        assert!(erlang_b(8, 4.0) < erlang_b(4, 4.0));
    }

    #[test]
    fn erlang_c_known_values() {
        // M/M/1: C = rho
        assert!((erlang_c(1, 0.8) - 0.8).abs() < 1e-12);
        // M/M/2 at a = 1.5: C = 0.6428571428571...
        assert!((erlang_c(2, 1.5) - 9.0 / 14.0).abs() < 1e-12);
        // saturation clamps to 1
        assert_eq!(erlang_c(2, 2.0), 1.0);
        assert_eq!(erlang_c(2, 5.0), 1.0);
        assert_eq!(erlang_c(3, 0.0), 0.0);
    }

    #[test]
    fn ks_statistic_detects_fit_and_misfit() {
        // exact uniform grid points against the U(0,1) CDF: D = 1/(2n)
        let xs: Vec<f64> = (0..100).map(|i| (i as f64 + 0.5) / 100.0).collect();
        let d = ks_statistic(&xs, |x| x.clamp(0.0, 1.0));
        assert!((d - 0.005).abs() < 1e-12, "D = {d}");
        // the same sample against a wrong CDF is far off
        let d_bad = ks_statistic(&xs, |x| (x / 2.0).clamp(0.0, 1.0));
        assert!(d_bad > 0.4, "D = {d_bad}");
        assert!(ks_statistic(&[], |_| 0.5).is_nan());
    }

    #[test]
    fn chi_squared_statistic_basics() {
        // perfect fit: 0
        assert_eq!(chi_squared_statistic(&[10.0, 20.0], &[10.0, 20.0]), 0.0);
        // one bin off by 5 against expectation 10: 25/10
        let x2 = chi_squared_statistic(&[15.0, 20.0], &[10.0, 20.0]);
        assert!((x2 - 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "expected bin count")]
    fn chi_squared_rejects_empty_expected_bins() {
        chi_squared_statistic(&[1.0], &[0.0]);
    }

    #[test]
    fn weighted_mean_basics() {
        assert!((weighted_mean(&[1.0, 3.0], &[1.0, 3.0]) - 2.5).abs() < 1e-12);
        assert!(weighted_mean(&[1.0], &[0.0]).is_nan());
    }

    #[test]
    fn fraction_below() {
        let v = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0, 1.0, 1.0, 1.0];
        assert!((weighted_fraction_below(&v, &w, 2.5) - 0.5).abs() < 1e-12);
        assert_eq!(weighted_fraction_below(&v, &w, 0.5), 0.0);
        assert_eq!(weighted_fraction_below(&v, &w, 10.0), 1.0);
    }

    #[test]
    fn fraction_below_weighted() {
        // 90% of records have latency 1s, 10% have 100s
        let v = [1.0, 100.0];
        let w = [9.0, 1.0];
        assert!((weighted_fraction_below(&v, &w, 4.0) - 0.9).abs() < 1e-12);
    }
}

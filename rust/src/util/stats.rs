//! Descriptive statistics for metric series: streaming moments (Welford),
//! exact quantiles, and weighted quantiles (used for per-record latency
//! percentiles where each hour is weighted by its arrival count).

/// Streaming mean/variance accumulator (Welford's algorithm).
#[derive(Debug, Clone, Default)]
pub struct Welford {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Welford {
    /// Fresh accumulator (count 0; moments are NaN until pushed).
    pub fn new() -> Self {
        Welford {
            n: 0,
            mean: 0.0,
            m2: 0.0,
            min: f64::INFINITY,
            max: f64::NEG_INFINITY,
        }
    }

    /// Fold one sample into the running moments.
    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    /// Number of samples pushed.
    pub fn count(&self) -> u64 {
        self.n
    }

    /// Sample mean (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.mean
        }
    }

    /// Population variance.
    pub fn variance(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.m2 / self.n as f64
        }
    }

    /// Population standard deviation.
    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    /// Smallest sample seen (NaN when empty).
    pub fn min(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.min
        }
    }

    /// Largest sample seen (NaN when empty).
    pub fn max(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.max
        }
    }
}

/// Exact quantile of a sample (linear interpolation between order
/// statistics, the "type 7" definition used by numpy's default).
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!((0.0..=1.0).contains(&q), "quantile {q} outside [0,1]");
    if xs.is_empty() {
        return f64::NAN;
    }
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).expect("NaN in quantile input"));
    quantile_sorted(&v, q)
}

/// Quantile of an already-sorted slice.
pub fn quantile_sorted(v: &[f64], q: f64) -> f64 {
    if v.is_empty() {
        return f64::NAN;
    }
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        let frac = pos - lo as f64;
        v[lo] * (1.0 - frac) + v[hi] * frac
    }
}

/// Median (the 0.5 quantile) of a sample.
pub fn median(xs: &[f64]) -> f64 {
    quantile(xs, 0.5)
}

/// Arithmetic mean; NaN on an empty slice.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return f64::NAN;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Weighted quantile: the smallest value `v` such that the summed weight of
/// samples `<= v` reaches `q` of the total weight. Zero-weight samples are
/// ignored. Used for per-record latency stats where each simulated hour
/// carries `arrivals(hour)` records.
pub fn weighted_quantile(values: &[f64], weights: &[f64], q: f64) -> f64 {
    assert_eq!(values.len(), weights.len());
    assert!((0.0..=1.0).contains(&q));
    let mut idx: Vec<usize> = (0..values.len()).filter(|&i| weights[i] > 0.0).collect();
    if idx.is_empty() {
        return f64::NAN;
    }
    idx.sort_by(|&a, &b| values[a].partial_cmp(&values[b]).expect("NaN value"));
    let total: f64 = idx.iter().map(|&i| weights[i]).sum();
    let target = q * total;
    let mut acc = 0.0;
    for &i in &idx {
        acc += weights[i];
        if acc >= target {
            return values[i];
        }
    }
    values[*idx.last().unwrap()]
}

/// Weighted mean; NaN on zero total weight.
pub fn weighted_mean(values: &[f64], weights: &[f64]) -> f64 {
    assert_eq!(values.len(), weights.len());
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return f64::NAN;
    }
    values
        .iter()
        .zip(weights)
        .map(|(v, w)| v * w)
        .sum::<f64>()
        / total
}

/// Fraction of weight whose value satisfies `value <= limit`.
/// This is the paper's "% latency met" column.
pub fn weighted_fraction_below(values: &[f64], weights: &[f64], limit: f64) -> f64 {
    assert_eq!(values.len(), weights.len());
    let total: f64 = weights.iter().sum();
    if total <= 0.0 {
        return f64::NAN;
    }
    values
        .iter()
        .zip(weights)
        .filter(|(v, _)| **v <= limit)
        .map(|(_, w)| w)
        .sum::<f64>()
        / total
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn welford_matches_direct() {
        let xs = [1.0, 2.0, 3.0, 4.0, 10.0];
        let mut w = Welford::new();
        for &x in &xs {
            w.push(x);
        }
        assert_eq!(w.count(), 5);
        assert!((w.mean() - 4.0).abs() < 1e-12);
        let var = xs.iter().map(|x| (x - 4.0_f64).powi(2)).sum::<f64>() / 5.0;
        assert!((w.variance() - var).abs() < 1e-12);
        assert_eq!(w.min(), 1.0);
        assert_eq!(w.max(), 10.0);
    }

    #[test]
    fn welford_empty_is_nan() {
        let w = Welford::new();
        assert!(w.mean().is_nan());
        assert!(w.variance().is_nan());
    }

    #[test]
    fn quantile_basics() {
        let xs = [3.0, 1.0, 2.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert!((quantile(&xs, 0.5) - 2.5).abs() < 1e-12);
    }

    #[test]
    fn quantile_single_element() {
        assert_eq!(quantile(&[7.0], 0.3), 7.0);
    }

    #[test]
    fn quantile_empty_nan() {
        assert!(quantile(&[], 0.5).is_nan());
    }

    #[test]
    fn median_odd() {
        assert_eq!(median(&[5.0, 1.0, 3.0]), 3.0);
    }

    #[test]
    fn weighted_quantile_skews_with_weight() {
        let values = [1.0, 2.0, 3.0];
        // almost all weight on 3.0
        assert_eq!(weighted_quantile(&values, &[0.01, 0.01, 100.0], 0.5), 3.0);
        // uniform weights: median is the middle value
        assert_eq!(weighted_quantile(&values, &[1.0, 1.0, 1.0], 0.5), 2.0);
    }

    #[test]
    fn weighted_quantile_ignores_zero_weight() {
        let v = [100.0, 1.0, 2.0];
        let w = [0.0, 1.0, 1.0];
        assert_eq!(weighted_quantile(&v, &w, 1.0), 2.0);
    }

    #[test]
    fn weighted_mean_basics() {
        assert!((weighted_mean(&[1.0, 3.0], &[1.0, 3.0]) - 2.5).abs() < 1e-12);
        assert!(weighted_mean(&[1.0], &[0.0]).is_nan());
    }

    #[test]
    fn fraction_below() {
        let v = [1.0, 2.0, 3.0, 4.0];
        let w = [1.0, 1.0, 1.0, 1.0];
        assert!((weighted_fraction_below(&v, &w, 2.5) - 0.5).abs() < 1e-12);
        assert_eq!(weighted_fraction_below(&v, &w, 0.5), 0.0);
        assert_eq!(weighted_fraction_below(&v, &w, 10.0), 1.0);
    }

    #[test]
    fn fraction_below_weighted() {
        // 90% of records have latency 1s, 10% have 100s
        let v = [1.0, 100.0];
        let w = [9.0, 1.0];
        assert!((weighted_fraction_below(&v, &w, 4.0) - 0.9).abs() < 1e-12);
    }
}

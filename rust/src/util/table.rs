//! ASCII table rendering for paper-style tables (Tables I–IV) and
//! experiment/simulation comparisons in the CLI and benches.

/// Column alignment.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Align {
    /// Pad on the right (labels).
    Left,
    /// Pad on the left (numbers).
    Right,
}

/// A simple table builder: header + rows of strings, rendered with box
/// drawing suitable for terminals and monospace docs.
#[derive(Debug, Clone)]
pub struct Table {
    title: Option<String>,
    header: Vec<String>,
    aligns: Vec<Align>,
    rows: Vec<Vec<String>>,
}

impl Table {
    /// Table with the given column headers (first column left-aligned,
    /// the rest right-aligned by default).
    pub fn new(header: &[&str]) -> Self {
        Table {
            title: None,
            aligns: header
                .iter()
                .enumerate()
                .map(|(i, _)| if i == 0 { Align::Left } else { Align::Right })
                .collect(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Set a title line printed above the table.
    pub fn with_title(mut self, title: &str) -> Self {
        self.title = Some(title.to_string());
        self
    }

    /// Override alignment for one column (default: first left, rest right).
    pub fn align(mut self, col: usize, a: Align) -> Self {
        self.aligns[col] = a;
        self
    }

    /// Append a row (must match the header arity).
    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.header.len(),
            "row arity mismatch: {cells:?}"
        );
        self.rows.push(cells);
        self
    }

    /// Append a row of string slices.
    pub fn row_strs(&mut self, cells: &[&str]) -> &mut Self {
        self.row(cells.iter().map(|s| s.to_string()).collect())
    }

    /// Number of body rows appended so far.
    pub fn n_rows(&self) -> usize {
        self.rows.len()
    }

    /// Render with box-drawing separators.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut widths: Vec<usize> = self.header.iter().map(|h| h.chars().count()).collect();
        for row in &self.rows {
            for (i, c) in row.iter().enumerate() {
                widths[i] = widths[i].max(c.chars().count());
            }
        }
        let sep = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String], aligns: &[Align]| {
            let mut s = String::from("|");
            for i in 0..ncol {
                let pad = widths[i] - cells[i].chars().count();
                match aligns[i] {
                    Align::Left => {
                        s.push(' ');
                        s.push_str(&cells[i]);
                        s.push_str(&" ".repeat(pad + 1));
                    }
                    Align::Right => {
                        s.push_str(&" ".repeat(pad + 1));
                        s.push_str(&cells[i]);
                        s.push(' ');
                    }
                }
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if let Some(t) = &self.title {
            out.push_str(t);
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.header, &vec![Align::Left; ncol]));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for row in &self.rows {
            out.push_str(&fmt_row(row, &self.aligns));
            out.push('\n');
        }
        out.push_str(&sep);
        out.push('\n');
        out
    }
}

/// Format a float with `prec` decimal places, trimming to at most that.
pub fn fnum(v: f64, prec: usize) -> String {
    if v.is_nan() {
        return "-".to_string();
    }
    format!("{v:.prec$}")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_header_and_rows() {
        let mut t = Table::new(&["model", "max rec/s", "$/hr"]);
        t.row_strs(&["blocking-write", "1.95", "0.82"]);
        t.row_strs(&["no-blocking-write", "6.15", "7.03"]);
        let s = t.render();
        assert!(s.contains("| model "));
        assert!(s.contains("| blocking-write "));
        // numeric columns right-aligned: value ends right before the pipe
        assert!(s.contains("1.95 |"));
        assert_eq!(s.lines().filter(|l| l.starts_with('+')).count(), 3);
    }

    #[test]
    fn column_widths_expand_to_longest_cell() {
        let mut t = Table::new(&["a"]);
        t.row_strs(&["longer-cell-content"]);
        let s = t.render();
        let line = s.lines().next().unwrap();
        assert_eq!(line.len(), "longer-cell-content".len() + 4);
    }

    #[test]
    #[should_panic(expected = "arity")]
    fn arity_mismatch_panics() {
        let mut t = Table::new(&["a", "b"]);
        t.row_strs(&["only-one"]);
    }

    #[test]
    fn title_prepended() {
        let t = Table::new(&["x"]).with_title("TABLE I: params");
        assert!(t.render().starts_with("TABLE I: params\n"));
    }

    #[test]
    fn fnum_formats() {
        assert_eq!(fnum(1.956, 2), "1.96");
        assert_eq!(fnum(f64::NAN, 2), "-");
        assert_eq!(fnum(3.0, 0), "3");
    }

    #[test]
    fn unicode_width_by_chars() {
        let mut t = Table::new(&["name"]);
        t.row_strs(&["héllo"]);
        let s = t.render();
        // all body lines should have equal char count
        let lens: Vec<usize> = s.lines().map(|l| l.chars().count()).collect();
        assert!(lens.windows(2).all(|w| w[0] == w[1]));
    }
}

//! Unit conversions and humanized formatting used across reports:
//! bytes ↔ MB/GB, seconds ↔ human durations, dollars/cents.

/// Bytes per mebibyte.
pub const MB: f64 = 1024.0 * 1024.0;
/// Bytes per gibibyte.
pub const GB: f64 = 1024.0 * MB;

/// Bytes → MiB.
pub fn bytes_to_mb(b: u64) -> f64 {
    b as f64 / MB
}

/// Bytes → GiB.
pub fn bytes_to_gb(b: u64) -> f64 {
    b as f64 / GB
}

/// `1536` → `"1.5 KiB"`, etc.
pub fn human_bytes(b: u64) -> String {
    const UNITS: &[&str] = &["B", "KiB", "MiB", "GiB", "TiB"];
    let mut v = b as f64;
    let mut i = 0;
    while v >= 1024.0 && i + 1 < UNITS.len() {
        v /= 1024.0;
        i += 1;
    }
    if i == 0 {
        format!("{b} B")
    } else {
        format!("{v:.1} {}", UNITS[i])
    }
}

/// `4000.0` seconds → `"1h06m40s"`; large values roll to days.
pub fn human_duration(mut secs: f64) -> String {
    if secs.is_nan() {
        return "-".into();
    }
    if secs < 0.0 {
        secs = 0.0;
    }
    let days = (secs / 86_400.0).floor() as u64;
    let rem = secs - days as f64 * 86_400.0;
    let h = (rem / 3600.0).floor() as u64;
    let m = ((rem - h as f64 * 3600.0) / 60.0).floor() as u64;
    let s = rem - h as f64 * 3600.0 - m as f64 * 60.0;
    if days > 0 {
        format!("{days}d{h:02}h")
    } else if h > 0 {
        format!("{h}h{m:02}m{s:02.0}s")
    } else if m > 0 {
        format!("{m}m{s:02.0}s")
    } else if s >= 1.0 {
        format!("{s:.1}s")
    } else {
        format!("{:.0}ms", s * 1000.0)
    }
}

/// Dollars with sensible precision: `0.0012` → `"$0.0012"`, `614.19` → `"$614.19"`.
pub fn dollars(v: f64) -> String {
    if v.is_nan() {
        "-".into()
    } else if v != 0.0 && v.abs() < 0.01 {
        format!("${v:.4}")
    } else {
        format!("${v:.2}")
    }
}

/// Cents (the unit of the paper's Table III).
pub fn cents(v: f64) -> String {
    format!("{v:.2}¢")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn byte_conversions() {
        assert_eq!(bytes_to_mb(1024 * 1024), 1.0);
        assert_eq!(bytes_to_gb(1024 * 1024 * 1024), 1.0);
    }

    #[test]
    fn human_bytes_scales() {
        assert_eq!(human_bytes(512), "512 B");
        assert_eq!(human_bytes(1536), "1.5 KiB");
        assert_eq!(human_bytes(5 * 1024 * 1024), "5.0 MiB");
    }

    #[test]
    fn human_duration_formats() {
        assert_eq!(human_duration(0.25), "250ms");
        assert_eq!(human_duration(12.3), "12.3s");
        assert_eq!(human_duration(75.0), "1m15s");
        assert_eq!(human_duration(4000.0), "1h06m40s");
        assert!(human_duration(86_400.0 * 406.0).starts_with("406d"));
        assert_eq!(human_duration(f64::NAN), "-");
    }

    #[test]
    fn money_formats() {
        assert_eq!(dollars(614.19), "$614.19");
        assert_eq!(dollars(0.0012), "$0.0012");
        assert_eq!(dollars(0.0), "$0.00");
        assert_eq!(cents(0.82), "0.82¢");
    }
}

//! Statistical validation: proving the sim kernel against ground truth.
//!
//! PlantD's value rests on the claim that its wind-tunnel simulations
//! predict real pipeline behaviour well enough to forecast cost (paper
//! §V–VI). Before this module, the only guard was the real-vs-sim parity
//! test with its deliberately loose 0.45 tolerance (wall-clock runs
//! carry OS noise). This subsystem holds the simulator itself to a far
//! tighter bar, in three layers:
//!
//! - [`oracle`] — **closed-form ground truth**: exact M/M/1, M/M/c, and
//!   M/M/c/K steady-state metrics (Erlang-B/C from
//!   [`crate::util::stats`]), FIFO sojourn distributions, and the
//!   hypoexponential end-to-end law of M/M/1 tandems;
//! - [`suite`] — a **conformance runner**: named [`ValidationCase`]s
//!   configure [`crate::sim::Station`]/[`crate::sim::Tandem`] to textbook
//!   assumptions and assert every DES metric lands within
//!   [`suite::DES_VS_ANALYTIC_REL_TOL`] (2%) of the oracle, with
//!   pass/fail verdicts rendered as a `util::table` and JSON;
//! - [`snapshot`] — a **golden-snapshot harness**: canonical
//!   oracle/suite/campaign/experiment reports serialized under
//!   `tests/golden/`, normalized and byte-compared on every run, with
//!   `--update` regeneration.
//!
//! A fourth, opt-in leg measures *speed* rather than accuracy:
//! `--suite perf` profiles the event loop stage-by-stage with
//! [`crate::sim::PerfRecorder`] on a canonical M/M/1 workload. It is
//! deliberately excluded from `all` — timings are machine-relative and
//! must never gate correctness runs (see `docs/PERF.md`).
//!
//! Drivable three ways: `plantd validate [--suite queueing|snapshots|
//! all|perf] [--update]`, the `Validation` resource kind (declarable in
//! manifests, executed by the controller), and the
//! `tests/validation_oracle.rs` / `tests/golden_snapshots.rs`
//! integration tests. See `docs/VALIDATION.md` for the formulas,
//! tolerance derivations, and snapshot workflow.

pub mod oracle;
pub mod snapshot;
pub mod suite;

use std::path::Path;

use crate::sim::PerfReport;
use crate::util::json::Json;

pub use oracle::QueueMetrics;
pub use snapshot::{SnapshotMode, SnapshotOutcome, SnapshotStatus};
pub use suite::{
    CaseResult, MetricCheck, QueueModel, SuiteReport, ValidationCase, ValidationSuite,
};

/// Everything one `validate` invocation produced: which suites ran and
/// their results. Shared by the CLI verb and the controller's
/// `Validation` resource arm, so the two entry points cannot drift.
pub struct ValidationRun {
    /// The queueing conformance report, if that suite was selected.
    pub queueing: Option<SuiteReport>,
    /// The snapshot outcomes, if that suite was selected.
    pub snapshots: Option<Vec<SnapshotOutcome>>,
    /// The kernel stage profile, if `--suite perf` was selected.
    /// Timings are machine-relative; only wiring sanity can fail.
    pub perf: Option<PerfReport>,
}

impl ValidationRun {
    /// Rendered human output for every suite that ran (tables + verdict
    /// lines; newline-terminated, print with `print!`).
    pub fn output(&self) -> String {
        let mut out = String::new();
        if let Some(report) = &self.queueing {
            out += &report.render();
        }
        if let Some(outcomes) = &self.snapshots {
            out += &snapshot::render(outcomes);
        }
        if let Some(report) = &self.perf {
            out += &report.render();
        }
        out
    }

    /// Total targets checked (queueing cases + snapshot subjects + the
    /// perf profile when selected).
    pub fn targets(&self) -> usize {
        self.queueing.as_ref().map_or(0, |r| r.results.len())
            + self.snapshots.as_ref().map_or(0, Vec::len)
            + usize::from(self.perf.is_some())
    }

    /// Names of failing targets, prefixed by suite
    /// (`queueing/mm1-fifo`, `snapshots/campaign-paper`).
    pub fn failed(&self) -> Vec<String> {
        let mut failed = Vec::new();
        if let Some(report) = &self.queueing {
            failed.extend(
                report
                    .results
                    .iter()
                    .filter(|r| !r.pass())
                    .map(|r| format!("queueing/{}", r.name)),
            );
        }
        if let Some(outcomes) = &self.snapshots {
            failed.extend(
                outcomes
                    .iter()
                    .filter(|o| !o.status.pass())
                    .map(|o| format!("snapshots/{}", o.name)),
            );
        }
        if let Some(report) = &self.perf {
            if !report.sane() {
                failed.push("perf/kernel".to_string());
            }
        }
        failed
    }

    /// One line per failing target *with its evidence* — the failing
    /// metrics (analytic vs measured, err vs tol) or the snapshot
    /// status. This travels in error messages, so a CI log or a
    /// resource condition is diagnosable without a local re-run.
    pub fn failure_details(&self) -> Vec<String> {
        let mut details = Vec::new();
        if let Some(report) = &self.queueing {
            for r in report.results.iter().filter(|r| !r.pass()) {
                let metrics: Vec<String> = r
                    .checks
                    .iter()
                    .filter(|c| !c.pass)
                    .map(|c| {
                        format!(
                            "{} analytic {:.6} measured {:.6} ({} err {:.4} >= {:.4})",
                            c.metric, c.analytic, c.measured, c.mode, c.err, c.tol
                        )
                    })
                    .collect();
                details.push(format!("queueing/{}: {}", r.name, metrics.join("; ")));
            }
        }
        if let Some(outcomes) = &self.snapshots {
            for o in outcomes.iter().filter(|o| !o.status.pass()) {
                details.push(format!("snapshots/{}: {}", o.name, o.status.label()));
            }
        }
        if let Some(report) = &self.perf {
            if !report.sane() {
                details.push(format!(
                    "perf/kernel: recorder measured nothing (events {}, rate {:.0}/s)",
                    report.events, report.events_per_s
                ));
            }
        }
        details
    }

    /// Machine-readable per-suite results (what the `Validation`
    /// resource stores in its status).
    pub fn status_json(&self, selection: &str) -> Json {
        let failed = self.failed();
        let mut fields = vec![("suite", Json::str(selection))];
        if let Some(report) = &self.queueing {
            fields.push(("queueing", report.to_json()));
        }
        if let Some(outcomes) = &self.snapshots {
            fields.push(("snapshots", snapshot::to_json(outcomes)));
        }
        if let Some(report) = &self.perf {
            fields.push(("perf", report.to_json()));
        }
        fields.push(("targets", Json::Num(self.targets() as f64)));
        fields.push((
            "failed",
            Json::arr(failed.iter().map(|f| Json::str(f.clone()))),
        ));
        Json::obj(fields)
    }
}

/// Arrivals profiled by the `perf` suite's canonical M/M/1 workload:
/// large enough for stable percentiles, small enough for a CI smoke.
pub const PERF_SUITE_ARRIVALS: usize = 200_000;

/// Run the selected suites (`queueing`, `snapshots`, `all`, or `perf`).
/// `mode` governs the snapshot leg only (the controller always passes
/// [`SnapshotMode::Verify`]; `--update` is CLI-only because it mutates
/// the golden tree). `perf` is opt-in only — never part of `all` — so
/// machine-relative timings cannot leak into correctness gates or the
/// `Validation` resource's default status. Unknown selections are an
/// error.
pub fn run_suites(
    selection: &str,
    threads: usize,
    golden_dir: &Path,
    mode: SnapshotMode,
) -> Result<ValidationRun, String> {
    if !matches!(selection, "queueing" | "snapshots" | "all" | "perf") {
        return Err(format!(
            "unknown suite '{selection}' (queueing|snapshots|all|perf)"
        ));
    }
    let queueing = matches!(selection, "queueing" | "all")
        .then(|| ValidationSuite::queueing().run(threads));
    let snapshots =
        matches!(selection, "snapshots" | "all").then(|| snapshot::check(golden_dir, mode));
    let perf = (selection == "perf")
        .then(|| crate::sim::profile_kernel(PERF_SUITE_ARRIVALS, 64));
    Ok(ValidationRun {
        queueing,
        snapshots,
        perf,
    })
}

//! Closed-form queueing-theory ground truth for the sim kernel.
//!
//! Everything here is *exact* (up to f64 rounding): steady-state metrics
//! of M/M/1, M/M/c, and M/M/c/K queues from the textbook formulas
//! (Erlang-C for the waiting probability, the truncated birth–death
//! chain for the loss system), plus the sojourn-time distribution of the
//! FIFO M/M/c and the hypoexponential end-to-end sojourn of an M/M/1
//! tandem (Burke's theorem makes each downstream station M/M/1 at the
//! same arrival rate; Reich's theorem makes a customer's per-station
//! sojourns independent, so the end-to-end law is the convolution of
//! exponentials).
//!
//! Two numeric regimes, deliberately separated:
//!
//! - [`mmc`] / [`mmck`] use **pure rational arithmetic** (add, multiply,
//!   divide — no `exp`/`ln`/`powf`), so their results are bit-identical
//!   on every IEEE-754 platform regardless of the libm in use. The
//!   committed golden snapshot (`tests/golden/oracle_closed_form.json`)
//!   locks these bytes.
//! - the distribution functions ([`sojourn_cdf_mmc`],
//!   [`sojourn_quantile_mmc`], [`hypoexp_cdf`], [`hypoexp_quantile`])
//!   need `exp`, whose last-ulp behaviour is libm-specific; they are
//!   used only in tolerance-based comparisons, never byte-compared.

use crate::util::stats::erlang_c;

/// Exact steady-state metrics of an M/M/c or M/M/c/K queue.
///
/// All waiting/sojourn figures are for **admitted** jobs (for a loss
/// system the lost arrivals never wait), matching what a simulation
/// measures from its completion log.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QueueMetrics {
    /// Number of servers `c`.
    pub servers: usize,
    /// Arrival rate λ (jobs per virtual second).
    pub lambda: f64,
    /// Per-server service rate μ.
    pub mu: f64,
    /// Waiting-room bound (max jobs *waiting*; `None` = unbounded).
    pub queue_cap: Option<usize>,
    /// Per-server utilization λ_eff / (c·μ).
    pub rho: f64,
    /// Probability an arrival is lost (0 for an unbounded queue).
    pub loss: f64,
    /// Admitted arrival rate λ·(1 − loss).
    pub lambda_eff: f64,
    /// Time-average number of *waiting* jobs L_q.
    pub lq: f64,
    /// Mean wait in queue of an admitted job W_q = L_q / λ_eff.
    pub wq: f64,
    /// Mean sojourn (wait + service) of an admitted job W = W_q + 1/μ.
    pub w: f64,
    /// Time-average number in system L = L_q + λ_eff/μ.
    pub l: f64,
}

/// Exact M/M/c steady state (unbounded queue). Requires stability
/// (`λ < c·μ`); panics otherwise, because none of the steady-state
/// quantities exist at or beyond saturation.
///
/// ```
/// use plantd::validate::oracle::mmc;
/// // M/M/1 at ρ = 0.8: W = 1/(μ−λ) = 5, Lq = ρ²/(1−ρ) = 3.2
/// let m = mmc(1, 0.8, 1.0);
/// assert!((m.w - 5.0).abs() < 1e-12);
/// assert!((m.lq - 3.2).abs() < 1e-12);
/// ```
pub fn mmc(servers: usize, lambda: f64, mu: f64) -> QueueMetrics {
    assert!(servers >= 1, "mmc needs at least one server");
    assert!(lambda > 0.0 && mu > 0.0, "rates must be positive");
    let c = servers as f64;
    let a = lambda / mu;
    assert!(
        a < c,
        "mmc requires a stable queue: offered load {a} >= {servers} servers"
    );
    let rho = a / c;
    let cw = erlang_c(servers, a);
    let lq = cw * rho / (1.0 - rho);
    let wq = lq / lambda;
    let w = wq + 1.0 / mu;
    let l = lq + a;
    QueueMetrics {
        servers,
        lambda,
        mu,
        queue_cap: None,
        rho,
        loss: 0.0,
        lambda_eff: lambda,
        lq,
        wq,
        w,
        l,
    }
}

/// Exact M/M/c/K steady state: `c` servers plus a waiting room of
/// `queue_cap` slots, so the system holds at most `K = c + queue_cap`
/// jobs and arrivals beyond that are lost. Matches
/// [`crate::sim::QueuePolicy::DropNewest`] exactly (its `capacity`
/// bounds *waiting* jobs, not jobs in service). Stable for any λ.
pub fn mmck(servers: usize, lambda: f64, mu: f64, queue_cap: usize) -> QueueMetrics {
    assert!(servers >= 1, "mmck needs at least one server");
    assert!(lambda > 0.0 && mu > 0.0, "rates must be positive");
    let k = servers + queue_cap;
    let c = servers as f64;
    let a = lambda / mu;
    // unnormalized birth–death weights: a^n/n! up to c, then geometric
    // with ratio a/c — a running product, no factorials or powf
    let mut terms = Vec::with_capacity(k + 1);
    let mut term = 1.0f64;
    terms.push(term);
    for n in 1..=k {
        if n <= servers {
            term = term * a / (n as f64);
        } else {
            term = term * a / c;
        }
        terms.push(term);
    }
    let total: f64 = terms.iter().sum();
    let p: Vec<f64> = terms.iter().map(|t| t / total).collect();
    let loss = p[k];
    let lambda_eff = lambda * (1.0 - loss);
    let mut lq = 0.0f64;
    for (n, pn) in p.iter().enumerate().skip(servers + 1) {
        lq += (n - servers) as f64 * pn;
    }
    let wq = lq / lambda_eff;
    let w = wq + 1.0 / mu;
    let l = lq + lambda_eff / mu;
    let rho = lambda_eff / (c * mu);
    QueueMetrics {
        servers,
        lambda,
        mu,
        queue_cap: Some(queue_cap),
        rho,
        loss,
        lambda_eff,
        lq,
        wq,
        w,
        l,
    }
}

/// CDF of the FIFO M/M/c **sojourn** time (wait + service).
///
/// The wait of an arriving job is 0 with probability `1 − C` (Erlang-C)
/// and `Exp(cμ − λ)` otherwise, independent of its own `Exp(μ)` service
/// (the PASTA + memorylessness argument), so with `η = cμ − λ`:
///
/// ```text
/// P(T > t) = (1−C)·e^(−μt) + C·(η·e^(−μt) − μ·e^(−ηt)) / (η − μ)
/// ```
///
/// with the `η → μ` limit `e^(−μt)·(1−C + C·(1+μt))`. For c = 1 this
/// collapses to the classic `T ~ Exp(μ − λ)`.
pub fn sojourn_cdf_mmc(servers: usize, lambda: f64, mu: f64, t: f64) -> f64 {
    assert!(servers >= 1 && lambda > 0.0 && mu > 0.0);
    let c = servers as f64;
    let a = lambda / mu;
    assert!(a < c, "sojourn distribution needs a stable queue");
    if t <= 0.0 {
        return 0.0;
    }
    let cw = erlang_c(servers, a);
    let eta = c * mu - lambda;
    let survival = if (eta - mu).abs() <= 1e-9 * mu {
        (-mu * t).exp() * (1.0 - cw + cw * (1.0 + mu * t))
    } else {
        (1.0 - cw) * (-mu * t).exp()
            + cw * (eta * (-mu * t).exp() - mu * (-eta * t).exp()) / (eta - mu)
    };
    1.0 - survival
}

/// Quantile of the FIFO M/M/c sojourn time: the `q`-th point of
/// [`sojourn_cdf_mmc`], found by deterministic bisection (the CDF is
/// continuous and strictly increasing on t > 0).
pub fn sojourn_quantile_mmc(servers: usize, lambda: f64, mu: f64, q: f64) -> f64 {
    assert!((0.0..1.0).contains(&q), "quantile {q} outside [0,1)");
    invert_cdf(|t| sojourn_cdf_mmc(servers, lambda, mu, t), q)
}

/// CDF of a hypoexponential distribution — the sum of independent
/// exponentials with *distinct* rates. Via partial fractions:
/// `P(T > t) = Σ_i w_i·e^(−r_i t)` with `w_i = Π_{j≠i} r_j/(r_j − r_i)`.
///
/// This is the end-to-end sojourn law of a FIFO M/M/1 tandem at arrival
/// rate λ with service rates μ_i: each station's sojourn is
/// `Exp(μ_i − λ)` (Burke), and a customer's per-station sojourns are
/// independent (Reich), so pass `rates = [μ_i − λ]`.
pub fn hypoexp_cdf(rates: &[f64], t: f64) -> f64 {
    assert!(!rates.is_empty(), "need at least one stage rate");
    for (i, ri) in rates.iter().enumerate() {
        assert!(*ri > 0.0, "rates must be positive");
        for rj in rates.iter().skip(i + 1) {
            assert!(
                (ri - rj).abs() > 1e-9 * ri.max(*rj),
                "hypoexp_cdf requires distinct rates, got {ri} and {rj}"
            );
        }
    }
    if t <= 0.0 {
        return 0.0;
    }
    let mut survival = 0.0f64;
    for (i, ri) in rates.iter().enumerate() {
        let mut w = 1.0f64;
        for (j, rj) in rates.iter().enumerate() {
            if j != i {
                w *= rj / (rj - ri);
            }
        }
        survival += w * (-ri * t).exp();
    }
    (1.0 - survival).clamp(0.0, 1.0)
}

/// Quantile of the hypoexponential distribution (see [`hypoexp_cdf`]),
/// by deterministic bisection.
pub fn hypoexp_quantile(rates: &[f64], q: f64) -> f64 {
    assert!((0.0..1.0).contains(&q), "quantile {q} outside [0,1)");
    invert_cdf(|t| hypoexp_cdf(rates, t), q)
}

/// Bisection inverse of a continuous, increasing CDF. 200 halvings from
/// a doubling bracket: deterministic and accurate to ~1 ulp of the
/// bracket width — far below the suite's 2% tolerances.
fn invert_cdf<F: Fn(f64) -> f64>(cdf: F, q: f64) -> f64 {
    if q <= 0.0 {
        return 0.0;
    }
    let mut hi = 1.0f64;
    let mut guard = 0;
    while cdf(hi) < q {
        hi *= 2.0;
        guard += 1;
        assert!(guard < 1100, "CDF never reaches {q}");
    }
    let mut lo = 0.0f64;
    for _ in 0..200 {
        let mid = 0.5 * (lo + hi);
        if cdf(mid) < q {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    0.5 * (lo + hi)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mm1_textbook_values() {
        let m = mmc(1, 0.8, 1.0);
        assert!((m.rho - 0.8).abs() < 1e-15);
        assert!((m.w - 5.0).abs() < 1e-12);
        assert!((m.wq - 4.0).abs() < 1e-12);
        assert!((m.lq - 3.2).abs() < 1e-12);
        assert!((m.l - 4.0).abs() < 1e-12);
        assert_eq!(m.loss, 0.0);
    }

    #[test]
    fn mmc2_textbook_values() {
        // a = 1.5, c = 2: C = 9/14, Wq = C/(cμ−λ) = 9/7, W = 9/7 + 1
        let m = mmc(2, 1.5, 1.0);
        assert!((m.rho - 0.75).abs() < 1e-15);
        assert!((m.wq - 9.0 / 7.0).abs() < 1e-12);
        assert!((m.w - 16.0 / 7.0).abs() < 1e-12);
        assert!((m.lq - 27.0 / 14.0).abs() < 1e-12);
    }

    #[test]
    fn littles_law_holds_everywhere() {
        for (c, lambda, mu) in [(1, 0.5, 1.0), (2, 1.5, 1.0), (4, 3.2, 1.0), (3, 0.4, 0.25)] {
            let m = mmc(c, lambda, mu);
            assert!((m.lq - m.lambda * m.wq).abs() < 1e-12, "Lq = λWq");
            assert!((m.l - m.lambda * m.w).abs() < 1e-12, "L = λW");
        }
    }

    #[test]
    #[should_panic(expected = "stable")]
    fn mmc_rejects_saturation() {
        mmc(2, 2.0, 1.0);
    }

    #[test]
    fn mmck_reduces_to_mmc_for_huge_waiting_rooms() {
        let bounded = mmck(2, 1.5, 1.0, 10_000);
        let unbounded = mmc(2, 1.5, 1.0);
        assert!(bounded.loss < 1e-12);
        assert!((bounded.wq - unbounded.wq).abs() < 1e-9);
        assert!((bounded.lq - unbounded.lq).abs() < 1e-9);
    }

    #[test]
    fn mmck_loss_grows_with_load_and_shrinks_with_room() {
        let a = mmck(2, 1.8, 1.0, 4);
        let b = mmck(2, 2.6, 1.0, 4);
        assert!(b.loss > a.loss, "more load, more loss");
        let c = mmck(2, 2.6, 1.0, 12);
        assert!(c.loss < b.loss, "more room, less loss");
        // an overloaded loss system still has finite, sane metrics
        assert!(b.rho < 1.0 && b.wq > 0.0 && b.lq > 0.0);
        // probabilities normalize: L = Lq + busy servers
        assert!((b.l - (b.lq + b.lambda_eff / b.mu)).abs() < 1e-12);
    }

    #[test]
    fn mm1k_matches_closed_form() {
        // M/M/1/K: p_K = (1−ρ)ρ^K / (1−ρ^(K+1))
        let (lambda, mu, cap) = (0.9, 1.0, 3usize); // K = 4
        let m = mmck(1, lambda, mu, cap);
        let rho = lambda / mu;
        let k = (cap + 1) as i32;
        let p_k = (1.0 - rho) * rho.powi(k) / (1.0 - rho.powi(k + 1));
        assert!((m.loss - p_k).abs() < 1e-12, "{} vs {p_k}", m.loss);
    }

    #[test]
    fn mm1_sojourn_is_exponential() {
        // c = 1: T ~ Exp(μ−λ), so F(t) = 1 − e^(−0.2t) at λ=0.8, μ=1
        for t in [0.1, 1.0, 5.0, 20.0] {
            let f = sojourn_cdf_mmc(1, 0.8, 1.0, t);
            let expect = 1.0 - (-0.2f64 * t).exp();
            assert!((f - expect).abs() < 1e-12, "t={t}: {f} vs {expect}");
        }
        // and the quantile inverts it: −ln(1−q)/η
        for q in [0.1, 0.5, 0.95, 0.99] {
            let t = sojourn_quantile_mmc(1, 0.8, 1.0, q);
            let expect = -(1.0 - q).ln() / 0.2;
            assert!((t - expect).abs() < 1e-9, "q={q}: {t} vs {expect}");
        }
    }

    #[test]
    fn mmc_sojourn_cdf_is_a_proper_distribution() {
        let cdf = |t| sojourn_cdf_mmc(4, 3.2, 1.0, t);
        assert_eq!(cdf(0.0), 0.0);
        assert!(cdf(1e6) > 1.0 - 1e-12);
        let mut prev = 0.0;
        for i in 1..200 {
            let f = cdf(i as f64 * 0.1);
            assert!(f >= prev, "CDF must be monotone");
            prev = f;
        }
        // mean from the distribution matches the closed-form W
        // (integrate survival numerically on a fine grid)
        let m = mmc(4, 3.2, 1.0);
        let dt = 0.001;
        let mut mean = 0.0;
        let mut t = 0.0;
        while t < 200.0 {
            mean += (1.0 - cdf(t + 0.5 * dt)) * dt;
            t += dt;
        }
        assert!((mean - m.w).abs() / m.w < 1e-3, "{mean} vs {}", m.w);
    }

    #[test]
    fn hypoexp_reduces_to_exponential_for_one_stage() {
        for t in [0.5, 2.0, 10.0] {
            let f = hypoexp_cdf(&[0.3], t);
            let expect = 1.0 - (-0.3f64 * t).exp();
            assert!((f - expect).abs() < 1e-12);
        }
    }

    #[test]
    fn hypoexp_two_stage_mean_matches_sum() {
        // E[T] = 1/r1 + 1/r2; check via numeric integration of survival
        let rates = [0.3, 0.55];
        let expect = 1.0 / 0.3 + 1.0 / 0.55;
        let dt = 0.001;
        let mut mean = 0.0;
        let mut t = 0.0;
        while t < 300.0 {
            mean += (1.0 - hypoexp_cdf(&rates, t + 0.5 * dt)) * dt;
            t += dt;
        }
        assert!((mean - expect).abs() / expect < 1e-3, "{mean} vs {expect}");
        // quantile round-trips through the CDF
        let t95 = hypoexp_quantile(&rates, 0.95);
        assert!((hypoexp_cdf(&rates, t95) - 0.95).abs() < 1e-9);
    }
}

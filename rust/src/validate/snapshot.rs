//! Golden-snapshot regression harness: canonical reports, serialized
//! under `tests/golden/`, byte-compared on every run.
//!
//! Every subject is a fixed-seed, fully deterministic artifact:
//!
//! - `oracle_closed_form.json` — the analytic oracle's rational-only
//!   metrics for the canonical suite. Pure `+ − × ÷` arithmetic, so the
//!   bytes are identical on *every* IEEE-754 platform; this file is
//!   committed and never bootstrapped.
//! - `queueing_suite_small.json` — measured DES metrics of the suite at
//!   1/20 horizons. Locks the kernel's event ordering, RNG streams, and
//!   Station semantics.
//! - `campaign_paper.json` — the paper campaign grid at seed 0xD5
//!   (the report `tests/campaign_determinism.rs` already proves
//!   thread-count-invariant).
//! - `experiment_sim.json` — a tiny sim-mode wind-tunnel run of all
//!   three paper variants, with the twins fitted from it.
//!
//! ## Normalization
//!
//! Floating-point snapshot bytes must be stable across *toolchains* but
//! sensitive to *behaviour*. Raw shortest-roundtrip formatting fails the
//! first requirement: several subjects sample through `ln`/`exp`, whose
//! last-ulp results are libm-specific. [`normalize`] therefore rewrites
//! every JSON number as a 9-significant-digit scientific string
//! (`{:.8e}`) before comparison — wide enough that a last-ulp libm
//! wiggle never flips a digit, tight enough that any real modelling or
//! ordering change does.
//!
//! Caveat: normalization absorbs *continuous* wobble only. A last-ulp
//! shift in a sampled event time could in principle flip a discrete
//! decision (the ordering of two near-tied events), which would move a
//! DES snapshot by more than a 9th digit. With continuous arrival and
//! service times the committed seeds contain no such near-ties, but the
//! guarantee is empirical, not structural — so regenerate DES snapshots
//! in the CI environment when in doubt. Only `oracle_closed_form.json`
//! (pure rational arithmetic, no libm at all) is platform-independent
//! by construction.
//!
//! ## `--update` etiquette
//!
//! `plantd validate --suite snapshots --update` regenerates every file.
//! Run it only when a PR *intends* to change results, commit the diff,
//! and say why in the PR description. CI re-runs `--update` and fails
//! if the tree changes (drift) or if generated snapshots were never
//! committed. A missing file under `Verify` is a failure, not a free
//! pass — `tests/golden_snapshots.rs` bootstraps missing files locally
//! and double-generates to prove determinism, but the bytes only become
//! a regression bar once committed.

use std::path::{Path, PathBuf};

use crate::campaign::{Campaign, CampaignRunner};
use crate::datagen::{DataSet, DataSetSpec};
use crate::experiment::{Experiment, ExperimentHarness};
use crate::loadgen::LoadPattern;
use crate::pipeline::VariantConfig;
use crate::twin::TwinParams;
use crate::util::json::Json;

use super::suite::ValidationSuite;

/// How the harness treats the golden directory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SnapshotMode {
    /// Compare against committed files; missing files fail.
    Verify,
    /// Regenerate every file (reporting whether it changed).
    Update,
    /// Compare existing files strictly, but write (and double-generate)
    /// missing ones — the in-tree test's first-run behaviour.
    BootstrapMissing,
}

/// Result of checking one subject.
#[derive(Debug, Clone)]
pub struct SnapshotOutcome {
    /// Subject name.
    pub name: &'static str,
    /// File the subject serializes to.
    pub path: PathBuf,
    /// What happened.
    pub status: SnapshotStatus,
}

/// Per-subject verdict.
#[derive(Debug, Clone)]
pub enum SnapshotStatus {
    /// Golden file present and byte-identical.
    Match,
    /// File (re)written by `Update`; bytes unchanged from the tree.
    Unchanged,
    /// File (re)written by `Update`; bytes differ from what was there
    /// (or the file was new).
    Updated,
    /// File was missing and `BootstrapMissing` wrote it (regeneration
    /// proved byte-identical).
    Bootstrapped,
    /// File missing under `Verify`.
    Missing,
    /// Bytes differ; holds a one-line description of the first
    /// difference.
    Drift(String),
    /// The golden file could not be read/written.
    Error(String),
}

impl SnapshotStatus {
    /// Whether this outcome counts as a pass.
    pub fn pass(&self) -> bool {
        matches!(
            self,
            SnapshotStatus::Match
                | SnapshotStatus::Unchanged
                | SnapshotStatus::Updated
                | SnapshotStatus::Bootstrapped
        )
    }

    /// Short display label.
    pub fn label(&self) -> String {
        match self {
            SnapshotStatus::Match => "match".into(),
            SnapshotStatus::Unchanged => "unchanged".into(),
            SnapshotStatus::Updated => "updated".into(),
            SnapshotStatus::Bootstrapped => "bootstrapped (commit me)".into(),
            SnapshotStatus::Missing => "MISSING (run --update)".into(),
            SnapshotStatus::Drift(d) => format!("DRIFT: {d}"),
            SnapshotStatus::Error(e) => format!("ERROR: {e}"),
        }
    }
}

/// One snapshot subject: a name, a target file, a generator.
pub struct Subject {
    /// Subject name (shown in tables).
    pub name: &'static str,
    /// File name under the golden directory.
    pub file: &'static str,
    /// Produce the (un-normalized) report JSON.
    pub generate: fn() -> Json,
}

/// The canonical subject list (see the module docs).
pub fn subjects() -> Vec<Subject> {
    vec![
        Subject {
            name: "oracle-closed-form",
            file: "oracle_closed_form.json",
            generate: || ValidationSuite::queueing().closed_form_json(),
        },
        Subject {
            name: "queueing-suite-small",
            file: "queueing_suite_small.json",
            generate: || ValidationSuite::queueing_sized(0.05).run(1).measured_json(),
        },
        Subject {
            name: "campaign-paper",
            file: "campaign_paper.json",
            generate: || {
                let campaign = Campaign::from_grid_name("paper", 0xD5)
                    .expect("the paper grid preset exists");
                CampaignRunner::new(1).run(&campaign).to_json()
            },
        },
        Subject {
            name: "experiment-sim",
            file: "experiment_sim.json",
            generate: experiment_sim_json,
        },
    ]
}

/// Tiny sim-mode wind-tunnel run (all three paper variants) plus the
/// twins fitted from it — the experiment/twin leg of the snapshot set.
fn experiment_sim_json() -> Json {
    let harness = ExperimentHarness::new(3000.0);
    let exp = Experiment::new(
        "golden-pulse",
        LoadPattern::steady(5.0, 2.0), // 10 zips: enough to exercise every stage
        DataSet::generate(DataSetSpec {
            payloads: 4,
            records_per_subsystem: 2,
            bad_rate: 0.0,
            seed: 9,
        }),
    );
    let mut records = Vec::new();
    let mut twins = Vec::new();
    for cfg in VariantConfig::paper_variants() {
        let rec = harness
            .simulate(&cfg, &exp)
            .expect("sim mode is deterministic and infallible on this input");
        twins.push(TwinParams::fit(&rec).to_json());
        records.push(rec.to_json());
    }
    Json::obj(vec![
        ("experiment", Json::str("golden-pulse")),
        ("records", Json::arr(records)),
        ("twins", Json::arr(twins)),
    ])
}

/// Default golden directory: `$PLANTD_GOLDEN_DIR`, else `tests/golden`
/// (tests resolve it from the manifest dir instead — see
/// `tests/golden_snapshots.rs` — because `cargo` runs them with the
/// crate root, not the repo root, as the working directory).
pub fn default_golden_dir() -> PathBuf {
    std::env::var("PLANTD_GOLDEN_DIR")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("tests/golden"))
}

/// Rewrite every JSON number as a 9-significant-digit scientific string
/// (see the module docs for why). Applied to both sides of every
/// comparison, and to files before writing.
pub fn normalize(j: &Json) -> Json {
    match j {
        Json::Num(v) => Json::Str(sig9(*v)),
        Json::Arr(items) => Json::Arr(items.iter().map(normalize).collect()),
        Json::Obj(map) => Json::Obj(
            map.iter()
                .map(|(k, v)| (k.clone(), normalize(v)))
                .collect(),
        ),
        other => other.clone(),
    }
}

fn sig9(v: f64) -> String {
    if v.is_finite() {
        format!("{v:.8e}")
    } else {
        format!("{v}")
    }
}

/// The exact bytes a subject's golden file holds: normalized, pretty,
/// newline-terminated.
pub fn render_subject(s: &Subject) -> String {
    let mut text = normalize(&(s.generate)()).to_string_pretty();
    text.push('\n');
    text
}

/// One-line description of the first byte-level difference.
fn first_diff(golden: &str, generated: &str) -> String {
    for (i, (lg, ln)) in golden.lines().zip(generated.lines()).enumerate() {
        if lg != ln {
            return format!("line {}: golden `{lg}` vs generated `{ln}`", i + 1);
        }
    }
    format!(
        "line count: golden {} vs generated {}",
        golden.lines().count(),
        generated.lines().count()
    )
}

/// Check (or update) every canonical subject against the golden
/// directory.
pub fn check(dir: &Path, mode: SnapshotMode) -> Vec<SnapshotOutcome> {
    check_subjects(dir, mode, &subjects())
}

/// [`check`] over an explicit subject list (tests use a cheap subset).
pub fn check_subjects(dir: &Path, mode: SnapshotMode, subjects: &[Subject]) -> Vec<SnapshotOutcome> {
    subjects
        .iter()
        .map(|s| {
            let path = dir.join(s.file);
            let generated = render_subject(s);
            let existing = std::fs::read_to_string(&path).ok();
            let status = match (existing, mode) {
                (Some(golden), SnapshotMode::Verify | SnapshotMode::BootstrapMissing) => {
                    if golden == generated {
                        SnapshotStatus::Match
                    } else {
                        SnapshotStatus::Drift(first_diff(&golden, &generated))
                    }
                }
                (None, SnapshotMode::Verify) => SnapshotStatus::Missing,
                (existing, SnapshotMode::Update) => {
                    let unchanged = existing.as_deref() == Some(generated.as_str());
                    match write_snapshot(&path, &generated) {
                        Ok(()) if unchanged => SnapshotStatus::Unchanged,
                        Ok(()) => SnapshotStatus::Updated,
                        Err(e) => SnapshotStatus::Error(e),
                    }
                }
                (None, SnapshotMode::BootstrapMissing) => {
                    // prove determinism before trusting the bytes: a
                    // second generation must reproduce them exactly
                    let second = render_subject(s);
                    if second != generated {
                        SnapshotStatus::Error(format!(
                            "non-deterministic generation: {}",
                            first_diff(&generated, &second)
                        ))
                    } else {
                        match write_snapshot(&path, &generated) {
                            Ok(()) => SnapshotStatus::Bootstrapped,
                            Err(e) => SnapshotStatus::Error(e),
                        }
                    }
                }
            };
            SnapshotOutcome {
                name: s.name,
                path,
                status,
            }
        })
        .collect()
}

fn write_snapshot(path: &Path, text: &str) -> Result<(), String> {
    if let Some(dir) = path.parent() {
        std::fs::create_dir_all(dir).map_err(|e| format!("{}: {e}", dir.display()))?;
    }
    std::fs::write(path, text).map_err(|e| format!("{}: {e}", path.display()))
}

/// Render outcomes as a `util::table` plus a verdict line.
pub fn render(outcomes: &[SnapshotOutcome]) -> String {
    let mut table = crate::util::table::Table::new(&["snapshot", "file", "status"])
        .align(1, crate::util::table::Align::Left)
        .align(2, crate::util::table::Align::Left)
        .with_title("GOLDEN SNAPSHOTS");
    for o in outcomes {
        table.row(vec![
            o.name.to_string(),
            o.path.display().to_string(),
            o.status.label(),
        ]);
    }
    let failed = outcomes.iter().filter(|o| !o.status.pass()).count();
    let verdict = if failed == 0 {
        format!("{} snapshots: all PASS\n", outcomes.len())
    } else {
        format!("{failed} of {} snapshots FAILED\n", outcomes.len())
    };
    format!("{}{verdict}", table.render())
}

/// Machine-readable outcomes (for the Validation resource's status).
pub fn to_json(outcomes: &[SnapshotOutcome]) -> Json {
    Json::arr(outcomes.iter().map(|o| {
        Json::obj(vec![
            ("name", Json::str(o.name)),
            ("file", Json::str(o.path.display().to_string())),
            ("status", Json::str(o.status.label())),
            ("pass", Json::Bool(o.status.pass())),
        ])
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn normalize_rewrites_numbers_recursively() {
        let j = Json::parse(r#"{"a": 0.8, "b": [1, 2.5], "c": {"d": 1000}, "s": "x"}"#).unwrap();
        let n = normalize(&j);
        assert_eq!(n.path(&["a"]).unwrap().as_str(), Some("8.00000000e-1"));
        assert_eq!(
            n.get("b").unwrap().as_arr().unwrap()[0].as_str(),
            Some("1.00000000e0")
        );
        assert_eq!(n.path(&["c", "d"]).unwrap().as_str(), Some("1.00000000e3"));
        assert_eq!(n.get("s").unwrap().as_str(), Some("x"));
    }

    #[test]
    fn sig9_is_nine_significant_digits() {
        assert_eq!(sig9(0.8), "8.00000000e-1");
        assert_eq!(sig9(5.0), "5.00000000e0");
        assert_eq!(sig9(-3.2), "-3.20000000e0");
        assert_eq!(sig9(0.0), "0.00000000e0");
        // a last-ulp wiggle does not move the string
        assert_eq!(sig9(0.1 + 0.2), sig9(0.3 + 1e-17));
    }

    #[test]
    fn first_diff_points_at_the_first_divergence() {
        let d = first_diff("a\nb\nc", "a\nB\nc");
        assert!(d.contains("line 2"), "{d}");
        assert!(d.contains('B'), "{d}");
        let d = first_diff("a\nb", "a\nb\nc");
        assert!(d.contains("line count"), "{d}");
    }

    /// The cheap subject subset the lifecycle test cycles through (the
    /// full set re-runs a campaign per check; the mechanics are
    /// identical).
    fn cheap_subjects() -> Vec<Subject> {
        vec![Subject {
            name: "oracle-closed-form",
            file: "oracle_closed_form.json",
            generate: || ValidationSuite::queueing().closed_form_json(),
        }]
    }

    #[test]
    fn verify_missing_update_drift_lifecycle() {
        let dir = std::env::temp_dir().join("plantd-snapshot-test");
        let _ = std::fs::remove_dir_all(&dir);
        let subjects = cheap_subjects();
        // Verify on an empty dir: everything Missing
        let outcomes = check_subjects(&dir, SnapshotMode::Verify, &subjects);
        assert!(outcomes
            .iter()
            .all(|o| matches!(o.status, SnapshotStatus::Missing)));
        assert!(outcomes.iter().all(|o| !o.status.pass()));
        // Update writes them all
        let outcomes = check_subjects(&dir, SnapshotMode::Update, &subjects);
        assert!(outcomes
            .iter()
            .all(|o| matches!(o.status, SnapshotStatus::Updated)));
        // Verify now matches byte-for-byte
        let outcomes = check_subjects(&dir, SnapshotMode::Verify, &subjects);
        assert!(
            outcomes
                .iter()
                .all(|o| matches!(o.status, SnapshotStatus::Match)),
            "{:?}",
            outcomes.iter().map(|o| o.status.label()).collect::<Vec<_>>()
        );
        // a second Update on the unchanged tree is byte-identical
        let outcomes = check_subjects(&dir, SnapshotMode::Update, &subjects);
        assert!(outcomes
            .iter()
            .all(|o| matches!(o.status, SnapshotStatus::Unchanged)));
        // corrupt one file: Verify reports drift with a located diff
        let victim = dir.join("oracle_closed_form.json");
        let mut text = std::fs::read_to_string(&victim).unwrap();
        text = text.replacen("8.00000000e-1", "8.00000001e-1", 1);
        std::fs::write(&victim, text).unwrap();
        let outcomes = check_subjects(&dir, SnapshotMode::Verify, &subjects);
        match &outcomes[0].status {
            SnapshotStatus::Drift(d) => assert!(d.contains("line"), "{d}"),
            other => panic!("expected drift, got {}", other.label()),
        }
        // BootstrapMissing compares strictly when the file exists...
        let outcomes = check_subjects(&dir, SnapshotMode::BootstrapMissing, &subjects);
        assert!(matches!(outcomes[0].status, SnapshotStatus::Drift(_)));
        // ...and writes (with a double-generation proof) when it doesn't
        std::fs::remove_file(&victim).unwrap();
        let outcomes = check_subjects(&dir, SnapshotMode::BootstrapMissing, &subjects);
        assert!(matches!(outcomes[0].status, SnapshotStatus::Bootstrapped));
        let outcomes = check_subjects(&dir, SnapshotMode::Verify, &subjects);
        assert!(matches!(outcomes[0].status, SnapshotStatus::Match));
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn render_and_json_outputs() {
        let outcomes = vec![SnapshotOutcome {
            name: "x",
            path: PathBuf::from("tests/golden/x.json"),
            status: SnapshotStatus::Match,
        }];
        let text = render(&outcomes);
        assert!(text.contains("GOLDEN SNAPSHOTS"));
        assert!(text.contains("all PASS"));
        let j = to_json(&outcomes);
        assert_eq!(j.as_arr().unwrap().len(), 1);
    }
}

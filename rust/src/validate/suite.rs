//! The conformance suite: named [`ValidationCase`]s that run the
//! [`crate::sim`] kernel under textbook assumptions (Poisson arrivals,
//! exponential service) and assert every measured metric lands within a
//! documented tolerance of the [`super::oracle`] closed form.
//!
//! ## Determinism
//!
//! Every case pre-samples its arrival and service streams from RNGs
//! derived with [`derive_seed`] from the case seed, *indexed by arrival
//! number* — RNG consumption is independent of event interleaving, so a
//! case's measurements are a pure function of its parameters. Cases are
//! independent, and the thread pool only distributes whole cases, so a
//! suite run is byte-identical at any thread count (the
//! `tests/validation_oracle.rs` 1-vs-8-thread test pins this).
//!
//! ## The tolerance
//!
//! The DES is exact given its inputs; the 2% budget
//! ([`DES_VS_ANALYTIC_REL_TOL`]) covers only finite-horizon statistical
//! error of the *estimators* (the oracle is the infinite-horizon limit).
//! Horizons are sized so every metric's standard error sits near or
//! below 1% at the committed seeds — about half the budget — which is
//! what lets the suite assert 2% where the real-vs-sim guard in
//! `tests/sim_parity.rs` must allow 45% for OS noise. See
//! `docs/VALIDATION.md` for the derivation per metric.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Mutex;

use crate::sim::{derive_seed, Discipline, QueuePolicy, Served, StationConfig, Tandem};
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::stats;
use crate::util::table::{fnum, Table};

use super::oracle;

/// Relative tolerance for DES-vs-closed-form metric agreement. This is
/// the bar every future `sim/` refactor is judged against (the
/// real-vs-sim tolerance in `tests/sim_parity.rs` stays separate and
/// much looser, because wall-clock runs carry OS noise).
pub const DES_VS_ANALYTIC_REL_TOL: f64 = 0.02;

/// Absolute tolerance for the Kolmogorov–Smirnov distance between the
/// empirical sojourn distribution and the analytic CDF. The samples are
/// autocorrelated, so classical critical values do not apply; at the
/// suite's horizons the observed D sits below 0.005, and 0.02 flags any
/// real distributional break (a wrong service law lands at D > 0.1).
pub const KS_ABS_TOL: f64 = 0.02;

/// Stream tag for the arrival process (see [`derive_seed`]).
const ARRIVAL_STREAM: u64 = 0xA221;
/// Stream tag for per-station service processes.
const SERVICE_STREAM: u64 = 0x5E2C;

/// The queueing system a case exercises, configured to assumptions the
/// oracle can match exactly.
#[derive(Debug, Clone)]
pub enum QueueModel {
    /// One station: `servers` in parallel, exponential service at `mu`,
    /// Poisson arrivals at `lambda`. `queue_cap` bounds *waiting* jobs
    /// (M/M/c/K with K = servers + cap, via
    /// [`QueuePolicy::DropNewest`]); `None` is the unbounded M/M/c.
    Mmc {
        /// Parallel servers.
        servers: usize,
        /// Arrival rate, jobs per virtual second.
        lambda: f64,
        /// Per-server service rate.
        mu: f64,
        /// Waiting-room bound (`None` = unbounded).
        queue_cap: Option<usize>,
        /// Service order of waiting jobs. Mean-value checks hold for
        /// both (FIFO and non-preemptive LIFO share every time-average
        /// and mean by work conservation + Little's law); the
        /// distributional checks (quantiles, KS) run only under FIFO,
        /// where the oracle knows the sojourn law.
        discipline: Discipline,
    },
    /// A series of single-server FIFO stations, exponential service at
    /// `mus[i]`, Poisson arrivals at `lambda` into station 0. Burke +
    /// Reich make the end-to-end sojourn the independent sum of the
    /// per-station M/M/1 sojourns.
    TandemMm1 {
        /// Arrival rate into the first station.
        lambda: f64,
        /// Per-station service rates (all must exceed `lambda`).
        mus: Vec<f64>,
    },
}

impl QueueModel {
    /// Arrival rate into the system.
    pub fn lambda(&self) -> f64 {
        match self {
            QueueModel::Mmc { lambda, .. } | QueueModel::TandemMm1 { lambda, .. } => *lambda,
        }
    }

    /// Per-station service rates, in pipeline order.
    fn service_rates(&self) -> Vec<f64> {
        match self {
            QueueModel::Mmc { mu, .. } => vec![*mu],
            QueueModel::TandemMm1 { mus, .. } => mus.clone(),
        }
    }

    /// Station configs implementing this model on the sim kernel.
    fn station_configs(&self) -> Vec<StationConfig> {
        match self {
            QueueModel::Mmc {
                servers,
                queue_cap,
                discipline,
                ..
            } => {
                let policy = match queue_cap {
                    Some(cap) => QueuePolicy::DropNewest { capacity: *cap },
                    None => QueuePolicy::Unbounded,
                };
                vec![StationConfig::single("mmc")
                    .with_servers(*servers)
                    .with_discipline(*discipline)
                    .with_policy(policy)]
            }
            QueueModel::TandemMm1 { mus, .. } => (0..mus.len())
                .map(|i| StationConfig::single(&format!("t{i}")))
                .collect(),
        }
    }
}

/// One named conformance case: a model, a horizon, a seed, a tolerance.
#[derive(Debug, Clone)]
pub struct ValidationCase {
    /// Case name (appears in tables, JSON, and snapshots).
    pub name: String,
    /// The queueing system under test.
    pub model: QueueModel,
    /// Horizon: number of arrivals to generate.
    pub arrivals: usize,
    /// Arrivals excluded from sojourn statistics while the system fills
    /// from empty (by arrival index; utilization and loss use the full
    /// run, where the start-up transient is O(W/horizon) — negligible).
    pub warmup: usize,
    /// Master seed for this case's arrival/service streams.
    pub seed: u64,
    /// Relative tolerance for every mean/ratio metric.
    pub tol_rel: f64,
}

/// One metric compared against its closed-form value.
#[derive(Debug, Clone)]
pub struct MetricCheck {
    /// Metric name (`w_mean`, `rho`, `t_p95`, …).
    pub metric: String,
    /// Closed-form (oracle) value.
    pub analytic: f64,
    /// DES measurement.
    pub measured: f64,
    /// |measured − analytic| / |analytic| (`rel` mode) or the raw
    /// statistic (`abs` mode, e.g. the KS distance).
    pub err: f64,
    /// Pass bar for `err`.
    pub tol: f64,
    /// `"rel"` or `"abs"`.
    pub mode: &'static str,
    /// Whether `err < tol`.
    pub pass: bool,
}

fn rel_check(metric: &str, analytic: f64, measured: f64, tol: f64) -> MetricCheck {
    let err = (measured - analytic).abs() / analytic.abs().max(1e-300);
    MetricCheck {
        metric: metric.to_string(),
        analytic,
        measured,
        err,
        tol,
        mode: "rel",
        pass: err < tol,
    }
}

fn abs_check(metric: &str, measured: f64, tol: f64) -> MetricCheck {
    MetricCheck {
        metric: metric.to_string(),
        analytic: 0.0,
        measured,
        err: measured,
        tol,
        mode: "abs",
        pass: measured < tol,
    }
}

/// Pre-sampled per-station service times in one flat arena
/// (station-major: entry `station * n + job`), built once per case.
///
/// Derivation and draw order are byte-for-byte the historical
/// per-station scheme — one RNG per station seeded
/// `derive_seed(seed, [SERVICE_STREAM, station, 0])`, `n` exponential
/// draws each, stations in pipeline order — so every measurement stays
/// bit-identical. What changed is the cost shape: the servicer's
/// per-batch lookup is one index into one allocation (no nested-`Vec`
/// pointer chase), and nothing re-derives an RNG stream per job.
struct ServiceSampler {
    /// Arrival-horizon stride (draws per station).
    n: usize,
    /// `rates.len() * n` samples, station-major.
    flat: Vec<f64>,
}

impl ServiceSampler {
    /// Draw `n` service times for every station in `rates`.
    fn sample(seed: u64, rates: &[f64], n: usize) -> Self {
        let mut flat = Vec::with_capacity(rates.len() * n);
        for (s, mu) in rates.iter().enumerate() {
            let mut rng = Rng::new(derive_seed(seed, [SERVICE_STREAM, s as u64, 0]));
            flat.extend((0..n).map(|_| rng.exponential(*mu)));
        }
        ServiceSampler { n, flat }
    }

    /// The pre-sampled service time of `job` at `station`.
    #[inline]
    fn service_s(&self, station: usize, job: usize) -> f64 {
        self.flat[station * self.n + job]
    }

    /// Total service `job` receives across all stations (summed in
    /// pipeline order, matching the historical per-station iteration).
    fn total_service_s(&self, job: usize) -> f64 {
        self.flat.iter().skip(job).step_by(self.n).sum()
    }
}

/// Everything one executed case produced.
#[derive(Debug, Clone)]
pub struct CaseResult {
    /// Case name.
    pub name: String,
    /// The seed the case ran with (for replay).
    pub seed: u64,
    /// Horizon in arrivals.
    pub arrivals: usize,
    /// Kernel events processed.
    pub events: u64,
    /// Virtual time of the last completion.
    pub makespan_s: f64,
    /// Per-metric comparisons.
    pub checks: Vec<MetricCheck>,
}

impl CaseResult {
    /// Whether every metric landed inside tolerance.
    pub fn pass(&self) -> bool {
        self.checks.iter().all(|c| c.pass)
    }
}

/// Execute one case: pre-sample the streams, run the kernel to
/// quiescence, measure, and compare against the oracle.
pub fn run_case(case: &ValidationCase) -> CaseResult {
    let n = case.arrivals;
    assert!(n > 0 && case.warmup < n, "degenerate horizon");
    let lambda = case.model.lambda();

    // pre-sampled streams, indexed by arrival number: RNG consumption is
    // independent of event order, so measurements are a pure function of
    // (case parameters, seed) at any thread count
    let mut arr_rng = Rng::new(derive_seed(case.seed, [ARRIVAL_STREAM, 0, 0]));
    let mut arrival_times = Vec::with_capacity(n);
    let mut t = 0.0f64;
    for _ in 0..n {
        t += arr_rng.exponential(lambda);
        arrival_times.push(t);
    }
    let rates = case.model.service_rates();
    let sampler = ServiceSampler::sample(case.seed, &rates, n);
    let n_stations = rates.len();

    let tandem = Tandem::new(case.model.station_configs());
    let arrivals: Vec<(f64, usize)> = arrival_times.iter().copied().zip(0..n).collect();
    let out = tandem.run(arrivals, |station, _start, jobs| {
        let job = jobs[0];
        Served {
            service_s: sampler.service_s(station, job),
            // the last station's batch IS the output; the kernel drops
            // `next` there, so skip the clone
            next: if station + 1 < n_stations {
                jobs.clone()
            } else {
                Vec::new()
            },
        }
    });

    let makespan = out.drained_s();
    let mut sojourns = Vec::new();
    let mut waits = Vec::new();
    for (tc, idx) in &out.completions {
        if *idx < case.warmup {
            continue;
        }
        let sojourn = tc - arrival_times[*idx];
        let svc = sampler.total_service_s(*idx);
        sojourns.push(sojourn);
        waits.push(sojourn - svc);
    }
    let w_mean = stats::mean(&sojourns);
    let wq_mean = stats::mean(&waits);
    let tol = case.tol_rel;

    let mut checks = Vec::new();
    match &case.model {
        QueueModel::Mmc {
            servers,
            lambda,
            mu,
            queue_cap,
            discipline,
        } => {
            let st = &out.stations[0];
            let util = st.busy_s / (*servers as f64 * makespan);
            let lq_meas = st.queue_area_s / makespan;
            match queue_cap {
                None => {
                    let m = oracle::mmc(*servers, *lambda, *mu);
                    checks.push(rel_check("rho", m.rho, util, tol));
                    checks.push(rel_check("w_mean", m.w, w_mean, tol));
                    checks.push(rel_check("wq_mean", m.wq, wq_mean, tol));
                    checks.push(rel_check("lq", m.lq, lq_meas, tol));
                    if *discipline == Discipline::Fifo {
                        for q in [0.5, 0.95] {
                            let analytic = oracle::sojourn_quantile_mmc(*servers, *lambda, *mu, q);
                            let measured = stats::quantile(&sojourns, q);
                            checks.push(rel_check(&format!("t_p{}", (q * 100.0) as u32), analytic, measured, tol));
                        }
                        let d = stats::ks_statistic(&sojourns, |x| {
                            oracle::sojourn_cdf_mmc(*servers, *lambda, *mu, x)
                        });
                        // D shrinks like 1/√n; floor the bar for short
                        // (sub-suite) horizons so sanity runs stay honest
                        let ks_tol = KS_ABS_TOL.max(3.0 / (sojourns.len() as f64).sqrt());
                        checks.push(abs_check("ks_sojourn", d, ks_tol));
                    }
                }
                Some(cap) => {
                    let m = oracle::mmck(*servers, *lambda, *mu, *cap);
                    let loss_meas = st.dropped as f64 / st.offered as f64;
                    checks.push(rel_check("rho", m.rho, util, tol));
                    checks.push(rel_check("loss", m.loss, loss_meas, tol));
                    checks.push(rel_check("w_mean", m.w, w_mean, tol));
                    checks.push(rel_check("wq_mean", m.wq, wq_mean, tol));
                    checks.push(rel_check("lq", m.lq, lq_meas, tol));
                }
            }
        }
        QueueModel::TandemMm1 { lambda, mus } => {
            let mut w_total = 0.0;
            for (i, mu) in mus.iter().enumerate() {
                let m = oracle::mmc(1, *lambda, *mu);
                w_total += m.w;
                let util = out.stations[i].busy_s / makespan;
                checks.push(rel_check(&format!("rho_{i}"), m.rho, util, tol));
                let lq_meas = out.stations[i].queue_area_s / makespan;
                checks.push(rel_check(&format!("lq_{i}"), m.lq, lq_meas, tol));
            }
            checks.push(rel_check("w_end_to_end", w_total, w_mean, tol));
            let stage_rates: Vec<f64> = mus.iter().map(|mu| mu - lambda).collect();
            for q in [0.5, 0.95] {
                let analytic = oracle::hypoexp_quantile(&stage_rates, q);
                let measured = stats::quantile(&sojourns, q);
                checks.push(rel_check(&format!("t_p{}", (q * 100.0) as u32), analytic, measured, tol));
            }
        }
    }

    CaseResult {
        name: case.name.clone(),
        seed: case.seed,
        arrivals: case.arrivals,
        events: out.events,
        makespan_s: makespan,
        checks,
    }
}

/// A named collection of cases, runnable on a thread pool.
#[derive(Debug, Clone)]
pub struct ValidationSuite {
    /// Suite name (appears in reports).
    pub name: String,
    /// The cases, run in declaration order.
    pub cases: Vec<ValidationCase>,
}

impl ValidationSuite {
    /// The canonical queueing conformance suite: M/M/1, M/M/c for
    /// c ∈ {2, 4}, M/M/c/K with loss, a 2-station tandem, and a LIFO
    /// variant — the ≥ 6 analytic cases the acceptance bar names, at
    /// full horizons (see `docs/VALIDATION.md` for the sizing).
    pub fn queueing() -> Self {
        Self::queueing_sized(1.0)
    }

    /// The queueing suite with horizons scaled by `scale` (0 < scale
    /// ≤ 1). The golden-snapshot harness uses a small fraction: the
    /// byte-lock cares about determinism, not statistical tightness, and
    /// short horizons keep `--update` fast.
    pub fn queueing_sized(scale: f64) -> Self {
        assert!(scale > 0.0 && scale <= 1.0, "scale must be in (0, 1]");
        let sized = |base: usize| ((base as f64 * scale) as usize).max(1000);
        let case = |name: &str, model: QueueModel, base: usize, seed: u64| ValidationCase {
            name: name.to_string(),
            model,
            arrivals: sized(base),
            warmup: sized(base) / 10,
            seed,
            tol_rel: DES_VS_ANALYTIC_REL_TOL,
        };
        ValidationSuite {
            name: "queueing".to_string(),
            cases: vec![
                case(
                    "mm1-fifo",
                    QueueModel::Mmc {
                        servers: 1,
                        lambda: 0.8,
                        mu: 1.0,
                        queue_cap: None,
                        discipline: Discipline::Fifo,
                    },
                    600_000,
                    0x11AD_1001,
                ),
                case(
                    "mmc-2",
                    QueueModel::Mmc {
                        servers: 2,
                        lambda: 1.5,
                        mu: 1.0,
                        queue_cap: None,
                        discipline: Discipline::Fifo,
                    },
                    600_000,
                    0x11AD_0002,
                ),
                case(
                    "mmc-4",
                    QueueModel::Mmc {
                        servers: 4,
                        lambda: 3.2,
                        mu: 1.0,
                        queue_cap: None,
                        discipline: Discipline::Fifo,
                    },
                    1_000_000,
                    0x11AD_1003,
                ),
                case(
                    "mmck-2-8",
                    QueueModel::Mmc {
                        servers: 2,
                        lambda: 2.4,
                        mu: 1.0,
                        queue_cap: Some(6),
                        discipline: Discipline::Fifo,
                    },
                    400_000,
                    0x11AD_0004,
                ),
                case(
                    "tandem-2",
                    QueueModel::TandemMm1 {
                        lambda: 0.7,
                        mus: vec![1.0, 1.25],
                    },
                    400_000,
                    0x11AD_0005,
                ),
                case(
                    "mm1-lifo",
                    QueueModel::Mmc {
                        servers: 1,
                        lambda: 0.7,
                        mu: 1.0,
                        queue_cap: None,
                        discipline: Discipline::Lifo,
                    },
                    600_000,
                    0x11AD_1006,
                ),
            ],
        }
    }

    /// Execute every case on `threads` workers (an atomic cursor over
    /// the case list; results land in their slot, so the report is
    /// byte-identical for any thread count).
    pub fn run(&self, threads: usize) -> SuiteReport {
        let n = self.cases.len();
        let next = AtomicUsize::new(0);
        let results: Mutex<Vec<Option<CaseResult>>> = Mutex::new(vec![None; n]);
        let workers = threads.max(1).min(n.max(1));
        std::thread::scope(|scope| {
            for _ in 0..workers {
                scope.spawn(|| loop {
                    let i = next.fetch_add(1, Ordering::SeqCst);
                    if i >= n {
                        break;
                    }
                    let result = run_case(&self.cases[i]);
                    results.lock().unwrap()[i] = Some(result);
                });
            }
        });
        SuiteReport {
            suite: self.name.clone(),
            results: results
                .into_inner()
                .unwrap()
                .into_iter()
                .map(|r| r.expect("every case executed"))
                .collect(),
        }
    }

    /// The oracle's closed-form metrics for every case, as JSON — pure
    /// rational arithmetic only (no `exp`-based quantiles), so the
    /// output is bit-identical on every IEEE-754 platform. This is the
    /// committed golden snapshot (`oracle_closed_form.json`).
    pub fn closed_form_json(&self) -> Json {
        let metric_obj = |m: &oracle::QueueMetrics| {
            Json::obj(vec![
                ("rho", Json::Num(m.rho)),
                ("loss", Json::Num(m.loss)),
                ("lambda_eff", Json::Num(m.lambda_eff)),
                ("lq", Json::Num(m.lq)),
                ("wq", Json::Num(m.wq)),
                ("w", Json::Num(m.w)),
                ("l", Json::Num(m.l)),
            ])
        };
        let cases: Vec<Json> = self
            .cases
            .iter()
            .map(|case| {
                let (model, metrics) = match &case.model {
                    QueueModel::Mmc {
                        servers,
                        lambda,
                        mu,
                        queue_cap,
                        discipline,
                    } => {
                        let m = match queue_cap {
                            None => oracle::mmc(*servers, *lambda, *mu),
                            Some(cap) => oracle::mmck(*servers, *lambda, *mu, *cap),
                        };
                        let mut fields = vec![
                            ("kind", Json::str("mmc")),
                            ("servers", Json::Num(*servers as f64)),
                            ("lambda", Json::Num(*lambda)),
                            ("mu", Json::Num(*mu)),
                            (
                                "discipline",
                                Json::str(match discipline {
                                    Discipline::Fifo => "fifo",
                                    Discipline::Lifo => "lifo",
                                }),
                            ),
                        ];
                        if let Some(cap) = queue_cap {
                            fields.push(("queue_cap", Json::Num(*cap as f64)));
                        }
                        (Json::obj(fields), metric_obj(&m))
                    }
                    QueueModel::TandemMm1 { lambda, mus } => {
                        let model = Json::obj(vec![
                            ("kind", Json::str("tandem-mm1")),
                            ("lambda", Json::Num(*lambda)),
                            ("mus", Json::arr(mus.iter().map(|m| Json::Num(*m)))),
                        ]);
                        let stations: Vec<Json> = mus
                            .iter()
                            .map(|mu| metric_obj(&oracle::mmc(1, *lambda, *mu)))
                            .collect();
                        let w_total: f64 =
                            mus.iter().map(|mu| oracle::mmc(1, *lambda, *mu).w).sum();
                        let metrics = Json::obj(vec![
                            ("stations", Json::arr(stations)),
                            ("w_end_to_end", Json::Num(w_total)),
                        ]);
                        (model, metrics)
                    }
                };
                Json::obj(vec![
                    ("name", Json::str(case.name.clone())),
                    ("model", model),
                    ("metrics", metrics),
                ])
            })
            .collect();
        Json::obj(vec![
            ("suite", Json::str(self.name.clone())),
            ("cases", Json::arr(cases)),
        ])
    }
}

/// Aggregated results of one suite run.
#[derive(Debug, Clone)]
pub struct SuiteReport {
    /// Suite name.
    pub suite: String,
    /// Per-case results, in suite order.
    pub results: Vec<CaseResult>,
}

impl SuiteReport {
    /// Whether every case passed.
    pub fn pass(&self) -> bool {
        self.results.iter().all(CaseResult::pass)
    }

    /// Total metric checks across all cases.
    pub fn n_checks(&self) -> usize {
        self.results.iter().map(|r| r.checks.len()).sum()
    }

    /// Render the per-metric comparison as a `util::table` plus a
    /// one-line verdict (newline-terminated; print with `print!`).
    pub fn render(&self) -> String {
        let mut table = Table::new(&[
            "case", "metric", "analytic", "measured", "err", "tol", "verdict",
        ])
        .with_title(&format!(
            "VALIDATION '{}': sim kernel vs closed-form oracle",
            self.suite
        ));
        for r in &self.results {
            for c in &r.checks {
                let (err, tol) = match c.mode {
                    "rel" => (format!("{:.3}%", c.err * 100.0), format!("{:.1}%", c.tol * 100.0)),
                    _ => (format!("{:.4}", c.err), format!("{:.2} abs", c.tol)),
                };
                table.row(vec![
                    r.name.clone(),
                    c.metric.clone(),
                    if c.mode == "rel" { fnum(c.analytic, 4) } else { "-".to_string() },
                    fnum(c.measured, 4),
                    err,
                    tol,
                    if c.pass { "pass".to_string() } else { "FAIL".to_string() },
                ]);
            }
        }
        let failed: Vec<&str> = self
            .results
            .iter()
            .filter(|r| !r.pass())
            .map(|r| r.name.as_str())
            .collect();
        let verdict = if failed.is_empty() {
            format!(
                "{} cases, {} checks: all PASS\n",
                self.results.len(),
                self.n_checks()
            )
        } else {
            format!(
                "{} of {} cases FAILED: {}\n",
                failed.len(),
                self.results.len(),
                failed.join(", ")
            )
        };
        format!("{}{verdict}", table.render())
    }

    /// Full machine-readable report (verdicts included).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("suite", Json::str(self.suite.clone())),
            ("pass", Json::Bool(self.pass())),
            (
                "cases",
                Json::arr(self.results.iter().map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(r.name.clone())),
                        ("seed", Json::str(format!("{:#x}", r.seed))),
                        ("arrivals", Json::Num(r.arrivals as f64)),
                        ("events", Json::Num(r.events as f64)),
                        ("makespan_s", Json::Num(r.makespan_s)),
                        ("pass", Json::Bool(r.pass())),
                        (
                            "checks",
                            Json::arr(r.checks.iter().map(|c| {
                                Json::obj(vec![
                                    ("metric", Json::str(c.metric.clone())),
                                    ("analytic", Json::Num(c.analytic)),
                                    ("measured", Json::Num(c.measured)),
                                    ("err", Json::Num(c.err)),
                                    ("tol", Json::Num(c.tol)),
                                    ("mode", Json::str(c.mode)),
                                    ("pass", Json::Bool(c.pass)),
                                ])
                            })),
                        ),
                    ])
                })),
            ),
        ])
    }

    /// Measured metrics only (no verdicts, no tolerances): the stable
    /// byte surface the golden-snapshot harness locks. Any change to the
    /// kernel's event ordering, the RNG streams, or the Station
    /// semantics moves these numbers.
    pub fn measured_json(&self) -> Json {
        Json::obj(vec![
            ("suite", Json::str(self.suite.clone())),
            (
                "cases",
                Json::arr(self.results.iter().map(|r| {
                    Json::obj(vec![
                        ("name", Json::str(r.name.clone())),
                        ("seed", Json::str(format!("{:#x}", r.seed))),
                        ("arrivals", Json::Num(r.arrivals as f64)),
                        ("events", Json::Num(r.events as f64)),
                        ("makespan_s", Json::Num(r.makespan_s)),
                        (
                            "measured",
                            Json::Obj(
                                r.checks
                                    .iter()
                                    .map(|c| (c.metric.clone(), Json::Num(c.measured)))
                                    .collect(),
                            ),
                        ),
                    ])
                })),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quick_case(discipline: Discipline) -> ValidationCase {
        ValidationCase {
            name: "quick".into(),
            model: QueueModel::Mmc {
                servers: 1,
                lambda: 0.5,
                mu: 1.0,
                queue_cap: None,
                discipline,
            },
            arrivals: 4000,
            warmup: 400,
            seed: 0xF00D,
            tol_rel: 0.25, // short horizon: only sanity, not the 2% bar
        }
    }

    #[test]
    fn service_sampler_matches_the_historical_nested_scheme_bitwise() {
        // the flat arena must reproduce the exact bits of the original
        // per-station Vec<Vec<f64>> pre-sampling — this is what keeps
        // every suite measurement (and golden snapshot) byte-identical
        let (seed, n) = (0x11AD_0005u64, 257usize);
        let rates = [1.0f64, 1.25, 0.8];
        let reference: Vec<Vec<f64>> = rates
            .iter()
            .enumerate()
            .map(|(s, mu)| {
                let mut rng = Rng::new(derive_seed(seed, [SERVICE_STREAM, s as u64, 0]));
                (0..n).map(|_| rng.exponential(*mu)).collect()
            })
            .collect();
        let sampler = ServiceSampler::sample(seed, &rates, n);
        for (s, station) in reference.iter().enumerate() {
            for (j, want) in station.iter().enumerate() {
                assert_eq!(
                    sampler.service_s(s, j).to_bits(),
                    want.to_bits(),
                    "station {s} job {j}"
                );
            }
        }
        for j in [0usize, 1, 100, n - 1] {
            let want: f64 = reference.iter().map(|st| st[j]).sum();
            assert_eq!(sampler.total_service_s(j).to_bits(), want.to_bits());
        }
    }

    #[test]
    fn run_case_is_deterministic() {
        let case = quick_case(Discipline::Fifo);
        let a = run_case(&case);
        let b = run_case(&case);
        assert_eq!(a.events, b.events);
        assert_eq!(a.makespan_s.to_bits(), b.makespan_s.to_bits());
        for (ca, cb) in a.checks.iter().zip(&b.checks) {
            assert_eq!(ca.measured.to_bits(), cb.measured.to_bits());
        }
    }

    #[test]
    fn quick_case_lands_in_loose_tolerance() {
        let r = run_case(&quick_case(Discipline::Fifo));
        assert!(r.pass(), "{:#?}", r.checks);
        // expected check set for an unbounded FIFO M/M/c
        let names: Vec<&str> = r.checks.iter().map(|c| c.metric.as_str()).collect();
        assert_eq!(
            names,
            vec!["rho", "w_mean", "wq_mean", "lq", "t_p50", "t_p95", "ks_sojourn"]
        );
    }

    #[test]
    fn lifo_case_skips_distributional_checks() {
        let r = run_case(&quick_case(Discipline::Lifo));
        let names: Vec<&str> = r.checks.iter().map(|c| c.metric.as_str()).collect();
        assert_eq!(names, vec!["rho", "w_mean", "wq_mean", "lq"]);
    }

    #[test]
    fn suite_has_the_six_canonical_cases() {
        let s = ValidationSuite::queueing();
        let names: Vec<&str> = s.cases.iter().map(|c| c.name.as_str()).collect();
        assert_eq!(
            names,
            vec!["mm1-fifo", "mmc-2", "mmc-4", "mmck-2-8", "tandem-2", "mm1-lifo"]
        );
        assert!(names.len() >= 6, "acceptance bar: >= 6 analytic cases");
        for c in &s.cases {
            assert_eq!(c.tol_rel, DES_VS_ANALYTIC_REL_TOL);
            assert!(c.warmup < c.arrivals);
        }
    }

    #[test]
    fn closed_form_json_is_pure_and_stable() {
        let s = ValidationSuite::queueing();
        let a = s.closed_form_json().to_string_pretty();
        let b = s.closed_form_json().to_string_pretty();
        assert_eq!(a, b);
        assert!(a.contains("\"mm1-fifo\""));
        assert!(a.contains("\"w_end_to_end\""));
        // horizon scaling must not move the closed form
        let small = ValidationSuite::queueing_sized(0.05).closed_form_json();
        assert_eq!(small.to_string_pretty(), a);
    }

    #[test]
    fn report_renders_and_serializes() {
        let suite = ValidationSuite {
            name: "tiny".into(),
            cases: vec![quick_case(Discipline::Fifo)],
        };
        let report = suite.run(2);
        let text = report.render();
        assert!(text.contains("VALIDATION 'tiny'"));
        assert!(text.contains("w_mean"));
        assert!(text.contains("all PASS"));
        let j = report.to_json();
        assert_eq!(j.get_str("suite"), Some("tiny"));
        assert_eq!(j.get("pass"), Some(&Json::Bool(true)));
        let m = report.measured_json();
        assert!(m.get("cases").is_some());
    }
}

//! Schema regression tests for the committed bench trajectories.
//!
//! `BENCH_sim.json` and `BENCH_hotpaths.json` at the workspace root are
//! the repo's PR-over-PR perf record (docs/PERF.md). These tests hold
//! them to the `util::bench` trajectory schema — version, metric names,
//! positive rates — and prove the append harness refuses malformed
//! entries instead of silently corrupting the record. They also pin the
//! PR 6 acceptance claim: the index-heap entry must show at least 2×
//! the events/sec of the BinaryHeap baseline recorded in the same file
//! (both measured on the same reference host; later `local` / CI
//! entries are machine-relative and deliberately not compared), and the
//! PR 7 claim: clustered fleet campaigns clear >= 10x the cells/sec of
//! the exhaustive run recorded alongside them, the PR 8 claim: dealing
//! the same grid to two loopback workers keeps >= 0.8x the local
//! cells/sec (the fleet protocol tax stays under 20%), the PR 9
//! claim: the adaptive SLO-frontier bisection simulates at most half
//! the cells an exhaustive sweep of the same load range would, and the
//! PR 10 claim: at 8 producer threads the SPSC-ring telemetry route
//! clears >= 3x the spans/sec of the mutex-shared span sink.

use std::path::{Path, PathBuf};

use plantd::util::bench;
use plantd::util::json::Json;

/// The committed trajectory files, resolved from the crate manifest —
/// NOT via `bench::workspace_root()`, so a `PLANTD_BENCH_DIR` override
/// in the environment cannot point this test away from the repo.
fn committed(file: &str) -> PathBuf {
    Path::new(env!("CARGO_MANIFEST_DIR"))
        .parent()
        .expect("rust/ sits one level below the workspace root")
        .join(file)
}

fn load(file: &str) -> Json {
    let path = committed(file);
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    Json::parse(&text).unwrap_or_else(|e| panic!("{}: {e}", path.display()))
}

fn entry_by_label<'a>(doc: &'a Json, label: &str) -> &'a Json {
    doc.get("entries")
        .and_then(Json::as_arr)
        .unwrap()
        .iter()
        .find(|e| e.get_str("label") == Some(label))
        .unwrap_or_else(|| panic!("no entry labeled '{label}'"))
}

#[test]
fn committed_trajectories_validate_against_the_schema() {
    for (file, bench_name) in [
        ("BENCH_sim.json", "sim_campaign"),
        ("BENCH_hotpaths.json", "perf_hotpaths"),
    ] {
        let doc = load(file);
        bench::validate_trajectory(&doc).unwrap_or_else(|e| panic!("{file}: {e}"));
        assert_eq!(doc.get_str("schema"), Some(bench::TRAJECTORY_SCHEMA), "{file}");
        assert_eq!(doc.get_u64("version"), Some(bench::TRAJECTORY_VERSION), "{file}");
        assert_eq!(doc.get_str("bench"), Some(bench_name), "{file}");
        let entries = doc.get("entries").and_then(Json::as_arr).unwrap();
        assert!(!entries.is_empty(), "{file}: trajectory must not be empty");
    }
}

#[test]
fn sim_trajectory_entries_carry_the_required_metrics() {
    let doc = load("BENCH_sim.json");
    for e in doc.get("entries").and_then(Json::as_arr).unwrap() {
        let m = e.get("metrics").unwrap();
        for name in ["cells_per_s", "events_per_s", "grid_mean_s", "cells", "threads"] {
            let v = m
                .get_f64(name)
                .unwrap_or_else(|| panic!("entry '{}' missing {name}", e.get_str("label").unwrap()));
            assert!(v.is_finite() && v >= 0.0);
        }
        assert!(m.get_f64("cells_per_s").unwrap() > 0.0, "rates must be positive");
        assert!(m.get_f64("events_per_s").unwrap() > 0.0, "rates must be positive");
    }
}

#[test]
fn hotpaths_trajectory_entries_carry_stage_percentiles() {
    // BENCH_hotpaths.json holds two entry shapes: kernel entries from
    // `perf_hotpaths` (stage percentiles + rates) and telemetry entries
    // from `telemetry_contention` (locked-vs-ring spans/sec at 1 and 8
    // producers, recognized by `spans_per_s_ring_8p`). Each shape must
    // carry its full metric set.
    let doc = load("BENCH_hotpaths.json");
    for e in doc.get("entries").and_then(Json::as_arr).unwrap() {
        let m = e.get("metrics").unwrap();
        let label = e.get_str("label").unwrap();
        if m.get_f64("spans_per_s_ring_8p").is_some() {
            for name in [
                "spans_per_s_locked_1p",
                "spans_per_s_locked_8p",
                "spans_per_s_ring_1p",
                "spans_per_s_ring_8p",
            ] {
                let v = m
                    .get_f64(name)
                    .unwrap_or_else(|| panic!("entry '{label}' missing {name}"));
                assert!(v > 0.0, "{name} = {v} must be a positive rate");
            }
            continue;
        }
        for stage in ["enqueue", "pop", "service_draw", "stats_accrue"] {
            for pct in ["p50", "p95", "p99"] {
                let name = format!("{stage}_{pct}_ns");
                let v = m
                    .get_f64(&name)
                    .unwrap_or_else(|| panic!("entry '{label}' missing {name}"));
                assert!(v.is_finite() && v >= 0.0, "{name} = {v}");
            }
        }
        assert!(m.get_f64("events_per_s").unwrap() > 0.0);
        assert!(m.get_f64("queue_ops_per_s").unwrap() > 0.0);
    }
}

#[test]
fn telemetry_ring_entry_triples_the_locked_rate() {
    // the PR 10 acceptance bar: at 8 producer threads the SPSC-ring
    // telemetry route must clear >= 3x the spans/sec of the mutex-shared
    // sink, recorded as one self-contained reference-host entry
    let doc = load("BENCH_hotpaths.json");
    let e = entry_by_label(&doc, "pr10-telemetry");
    assert_eq!(
        e.get_str("host"),
        Some("reference"),
        "the 3x claim is pinned on the reference host"
    );
    let m = e.get("metrics").unwrap();
    let locked = m.get_f64("spans_per_s_locked_8p").unwrap();
    let ring = m.get_f64("spans_per_s_ring_8p").unwrap();
    let ratio = ring / locked;
    assert!(
        ratio >= 3.0,
        "spans/sec ratio {ratio:.2} < 3.0 ({ring:.0} ring vs {locked:.0} locked)"
    );
    // and the locked route must actually collapse under contention —
    // that regression is the whole reason the rings exist
    let locked_1p = m.get_f64("spans_per_s_locked_1p").unwrap();
    assert!(
        locked < locked_1p,
        "locked sink at 8p ({locked:.0}) should be slower than at 1p ({locked_1p:.0})"
    );
}

#[test]
fn index_heap_entry_doubles_the_baseline_events_rate() {
    // the PR 6 acceptance bar: >= 2x events/sec over the pre-rewrite
    // baseline, recorded as same-host entries in the same trajectory
    for file in ["BENCH_sim.json", "BENCH_hotpaths.json"] {
        let doc = load(file);
        let base = entry_by_label(&doc, "pr6-baseline-binaryheap");
        let opt = entry_by_label(&doc, "pr6-indexheap");
        assert_eq!(
            base.get_str("host"),
            opt.get_str("host"),
            "{file}: the 2x claim only holds within one host"
        );
        let base_rate = base.get("metrics").unwrap().get_f64("events_per_s").unwrap();
        let opt_rate = opt.get("metrics").unwrap().get_f64("events_per_s").unwrap();
        let ratio = opt_rate / base_rate;
        assert!(
            ratio >= 2.0,
            "{file}: events/sec ratio {ratio:.2} < 2.0 ({opt_rate:.0} vs {base_rate:.0})"
        );
    }
}

#[test]
fn clustered_fleet_entry_is_an_order_of_magnitude_over_exhaustive() {
    // the PR 7 acceptance bar: cluster-and-extrapolate must clear >= 10x
    // cells/sec over the exhaustive run of the same fleet grid, recorded
    // as same-host same-size entries in the same trajectory
    let doc = load("BENCH_sim.json");
    let exhaustive = entry_by_label(&doc, "pr7-fleet-exhaustive");
    let clustered = entry_by_label(&doc, "pr7-fleet-clustered");
    assert_eq!(
        exhaustive.get_str("host"),
        clustered.get_str("host"),
        "the speedup claim only holds within one host"
    );
    let ex_m = exhaustive.get("metrics").unwrap();
    let cl_m = clustered.get("metrics").unwrap();
    assert_eq!(
        ex_m.get_f64("cells"),
        cl_m.get_f64("cells"),
        "both legs must cover the same fleet grid"
    );
    assert!(
        cl_m.get_f64("n_clusters").unwrap() < cl_m.get_f64("cells").unwrap(),
        "the clustered leg must actually merge cells"
    );
    let ex_rate = ex_m.get_f64("cells_per_s").unwrap();
    let cl_rate = cl_m.get_f64("cells_per_s").unwrap();
    let ratio = cl_rate / ex_rate;
    assert!(
        ratio >= 10.0,
        "cells/sec ratio {ratio:.1} < 10.0 ({cl_rate:.0} vs {ex_rate:.0})"
    );
}

#[test]
fn distributed_fleet_entry_stays_within_20pct_of_the_local_run() {
    // the PR 8 acceptance bar: dealing the fleet grid to two loopback
    // workers must keep >= 0.8x the cells/sec of the in-process run of
    // the same grid (the protocol tax — serialization, framing, TCP —
    // stays under 20%). The local baseline travels inside the entry so
    // the claim is self-contained and host-consistent.
    let doc = load("BENCH_sim.json");
    let exhaustive = entry_by_label(&doc, "pr7-fleet-exhaustive");
    let dist = entry_by_label(&doc, "pr8-dist-2workers");
    assert_eq!(
        exhaustive.get_str("host"),
        dist.get_str("host"),
        "the overhead claim only holds within one host"
    );
    let m = dist.get("metrics").unwrap();
    assert_eq!(
        m.get_f64("cells"),
        exhaustive.get("metrics").unwrap().get_f64("cells"),
        "both legs must cover the same fleet grid"
    );
    assert_eq!(m.get_f64("workers"), Some(2.0));
    assert!(m.get_f64("shard_cells").unwrap() >= 1.0);
    let baseline = m.get_f64("baseline_cells_per_s").unwrap();
    let rate = m.get_f64("cells_per_s").unwrap();
    let ratio = rate / baseline;
    assert!(
        ratio >= 0.8,
        "distributed cells/sec ratio {ratio:.2} < 0.8 ({rate:.1} vs {baseline:.1} local)"
    );
}

#[test]
fn explore_entry_simulates_at_most_half_the_exhaustive_cells() {
    // the PR 9 acceptance bar: `plantd explore` must find the SLO knee
    // by simulating <= 50% of the cells an exhaustive sweep of the same
    // {variant x scenario x load-step} grid would run
    let doc = load("BENCH_sim.json");
    let e = entry_by_label(&doc, "pr9-explore");
    let m = e.get("metrics").unwrap();
    let simulated = m.get_f64("cells_simulated").unwrap();
    let exhaustive = m.get_f64("cells_exhaustive").unwrap();
    let combos = m.get_f64("combos").unwrap();
    assert!(combos >= 2.0, "the frontier must cover several combinations");
    assert!(
        simulated >= combos,
        "every combination costs at least one probe"
    );
    assert_eq!(
        m.get_f64("cells"),
        Some(simulated),
        "the generic cells metric counts what was actually simulated"
    );
    let ratio = simulated / exhaustive;
    assert!(
        ratio <= 0.5,
        "bisection simulated {simulated:.0} of {exhaustive:.0} exhaustive \
         cells ({ratio:.2} > 0.50)"
    );
}

#[test]
fn append_harness_rejects_malformed_entries_without_corrupting_the_file() {
    let dir = std::env::temp_dir().join(format!("plantd-bench-schema-{}", std::process::id()));
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("BENCH_reject.json");
    let _ = std::fs::remove_file(&path);

    let good = bench::entry("ok", 1_754_611_200, "host", vec![("events_per_s", 10.0)]);
    bench::append_entry(&path, "rejectbench", good).unwrap();
    let before = std::fs::read_to_string(&path).unwrap();

    // every malformed shape is refused and the file stays byte-identical
    let malformed = [
        bench::entry("", 1, "h", vec![("a", 1.0)]),                    // empty label
        bench::entry("x", 0, "h", vec![("a", 1.0)]),                   // zero time
        bench::entry("x", 1, "", vec![("a", 1.0)]),                    // empty host
        bench::entry("x", 1, "h", vec![]),                             // no metrics
        bench::entry("x", 1, "h", vec![("events_per_s", 0.0)]),        // zero rate
        bench::entry("x", 1, "h", vec![("p50_ns", f64::INFINITY)]),    // non-finite
        bench::entry("x", 1, "h", vec![("p50_ns", -3.0)]),             // negative
        Json::obj(vec![("label", Json::str("x"))]),                    // missing fields
        Json::str("not an object"),                                    // wrong type
    ];
    for (i, bad) in malformed.into_iter().enumerate() {
        let err = bench::append_entry(&path, "rejectbench", bad)
            .expect_err(&format!("malformed entry {i} must be refused"));
        assert!(err.contains("refusing to append"), "{err}");
        assert_eq!(
            std::fs::read_to_string(&path).unwrap(),
            before,
            "malformed entry {i} mutated the trajectory"
        );
    }

    // appending to a trajectory owned by another bench is refused too
    let good2 = bench::entry("ok2", 2, "host", vec![("events_per_s", 11.0)]);
    assert!(bench::append_entry(&path, "somethingelse", good2).is_err());
    assert_eq!(std::fs::read_to_string(&path).unwrap(), before);
    let _ = std::fs::remove_file(&path);
}

#[test]
fn committed_trajectories_round_trip_through_the_writer() {
    // the files must stay parse -> serialize stable so bench appends
    // produce minimal diffs
    for file in ["BENCH_sim.json", "BENCH_hotpaths.json"] {
        let path = committed(file);
        let text = std::fs::read_to_string(&path).unwrap();
        let doc = Json::parse(&text).unwrap();
        assert_eq!(
            doc.to_string_pretty(),
            text,
            "{file} is not in canonical serialized form"
        );
    }
}

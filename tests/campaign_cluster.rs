//! Cluster-and-extrapolate guarantees, tested across module boundaries:
//! tolerance 0 is byte-identical to the exhaustive path at any thread
//! count, clustered reports themselves replay byte-identically across
//! thread counts, greedy clustering is deterministic and total under
//! random feature sets, and — the accuracy contract — extrapolated
//! M/M/c metrics land within the *reported* error bound of the PR-4
//! closed-form oracle.

use plantd::campaign::{cluster, Campaign, CampaignRunner, CellProvenance};
use plantd::datagen::DataSetSpec;
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::sim::{derive_seed, Served, StationConfig, Tandem};
use plantd::util::proptest::check;
use plantd::util::rng::Rng;
use plantd::validate::oracle;

/// 2 variants × 3 loads: two near-duplicate dev loads (mergeable at 5%
/// tolerance) and one hot load far outside it.
fn mixed_campaign(seed: u64) -> Campaign {
    Campaign::new("cluster-mix", seed)
        .variant(VariantConfig::blocking_write())
        .variant(VariantConfig::cpu_limited())
        .load("dev-a", LoadPattern::steady(6.0, 2.0))
        .load("dev-b", LoadPattern::steady(6.0, 2.02))
        .load("hot", LoadPattern::steady(6.0, 5.0))
        .dataset(
            "tiny",
            DataSetSpec {
                payloads: 4,
                records_per_subsystem: 3,
                bad_rate: 0.01,
                seed: 0,
            },
        )
}

#[test]
fn tolerance_zero_is_byte_identical_to_exhaustive_at_any_thread_count() {
    let campaign = mixed_campaign(0xC1D0);
    let exhaustive = CampaignRunner::new(1).run(&campaign);
    let baseline = exhaustive.to_json().to_string_pretty();
    for threads in [1, 2, 5] {
        let clustered = CampaignRunner::new(threads)
            .with_cluster_tolerance(0.0)
            .run(&campaign);
        assert!(
            clustered.clustering.is_none(),
            "tolerance 0 must not emit a cluster summary"
        );
        assert_eq!(
            clustered.to_json().to_string_pretty().as_bytes(),
            baseline.as_bytes(),
            "tolerance-0 clustered run must be byte-identical (threads={threads})"
        );
        assert_eq!(clustered.render(), exhaustive.render());
    }
}

#[test]
fn clustered_report_is_byte_identical_across_thread_counts() {
    let campaign = mixed_campaign(0x7E57);
    let serial = CampaignRunner::new(1)
        .with_cluster_tolerance(0.05)
        .run(&campaign);
    let summary = serial.clustering.as_ref().expect("cluster summary");
    assert!(
        summary.clusters.len() < campaign.n_cells(),
        "near-duplicate loads must actually merge"
    );
    let baseline = serial.to_json().to_string_pretty();
    for threads in [2, 4, 8] {
        let wide = CampaignRunner::new(threads)
            .with_cluster_tolerance(0.05)
            .run(&campaign);
        assert_eq!(
            wide.to_json().to_string_pretty().as_bytes(),
            baseline.as_bytes(),
            "clustered report must not depend on thread count (threads={threads})"
        );
    }
}

#[test]
fn greedy_clustering_is_deterministic_total_and_within_tolerance() {
    check("cluster-greedy-invariants", 60, |rng| {
        let n = rng.int_range(1, 40) as usize;
        let dims = rng.int_range(1, 6) as usize;
        let mut features: Vec<Vec<f64>> = Vec::with_capacity(n);
        for i in 0..n {
            if i > 0 && rng.chance(0.25) {
                // exact duplicates must still cluster deterministically
                let j = rng.int_range(0, i as i64 - 1) as usize;
                features.push(features[j].clone());
            } else {
                features.push(
                    (0..dims)
                        .map(|_| {
                            if rng.chance(0.2) {
                                0.0
                            } else {
                                rng.uniform(-5.0, 10.0)
                            }
                        })
                        .collect(),
                );
            }
        }
        let tolerance = if rng.chance(0.3) {
            0.0
        } else {
            rng.uniform(0.0, 0.6)
        };

        let a = cluster::cluster_greedy(&features, tolerance);
        let b = cluster::cluster_greedy(&features, tolerance);
        assert_eq!(a, b, "same input must yield the same clustering");

        // totality: every index lands in exactly one cluster
        let mut seen = vec![0u32; n];
        for (id, c) in a.clusters.iter().enumerate() {
            assert_eq!(
                c.members.first().copied(),
                Some(c.representative),
                "representative is the lowest-index member"
            );
            let mut prev = None;
            for &m in &c.members {
                if let Some(p) = prev {
                    assert!(m > p, "members must ascend");
                }
                prev = Some(m);
                seen[m] += 1;
                let asg = &a.assignment[m];
                assert_eq!(asg.cluster, id);
                let d = cluster::distance(&features[m], &features[c.representative]);
                assert_eq!(asg.distance.to_bits(), d.to_bits());
                if m == c.representative {
                    assert_eq!(asg.distance, 0.0);
                } else {
                    assert!(asg.distance <= tolerance);
                }
            }
        }
        assert!(seen.iter().all(|&s| s == 1), "assignment must be total");
        if tolerance <= 0.0 {
            assert!(a.is_identity(), "tolerance 0 is the identity clustering");
            assert_eq!(a.n_clusters(), n);
        }
    });
}

/// Measured rho / mean wait / mean sojourn from one DES run of an
/// unbounded M/M/c station (pre-sampled streams, same scheme as the
/// PR-4 validation suite).
struct MmcMeasurement {
    rho: f64,
    wq: f64,
    w: f64,
}

fn simulate_mmc(
    servers: usize,
    lambda: f64,
    mu: f64,
    seed: u64,
    arrivals: usize,
    warmup: usize,
) -> MmcMeasurement {
    let mut arr = Rng::new(derive_seed(seed, [0xA221, 0, 0]));
    let mut t = 0.0;
    let mut arrival_times = Vec::with_capacity(arrivals);
    for _ in 0..arrivals {
        t += arr.exponential(lambda);
        arrival_times.push(t);
    }
    let mut svc = Rng::new(derive_seed(seed, [0x5E2C, 0, 0]));
    let services: Vec<f64> = (0..arrivals).map(|_| svc.exponential(mu)).collect();

    let tandem = Tandem::new(vec![StationConfig::single("mmc").with_servers(servers)]);
    let jobs: Vec<(f64, usize)> = arrival_times.iter().copied().zip(0..arrivals).collect();
    let out = tandem.run(jobs, |_station, _start, batch| Served {
        service_s: services[batch[0]],
        next: Vec::new(),
    });

    let makespan = out.drained_s();
    let rho = out.stations[0].busy_s / (servers as f64 * makespan);
    let (mut wq_sum, mut w_sum, mut n) = (0.0, 0.0, 0usize);
    for (tc, idx) in &out.completions {
        if *idx < warmup {
            continue;
        }
        let sojourn = tc - arrival_times[*idx];
        wq_sum += sojourn - services[*idx];
        w_sum += sojourn;
        n += 1;
    }
    assert!(n > 0, "warmup must not swallow every completion");
    MmcMeasurement {
        rho,
        wq: wq_sum / n as f64,
        w: w_sum / n as f64,
    }
}

#[test]
fn extrapolated_mmc_metrics_land_within_the_reported_error_bound() {
    // a fleet of M/M/c cells: for each server count, five utilizations
    // of which only three are feature-distinct at 5% tolerance
    let mu = 1.0;
    let rhos = [0.60, 0.62, 0.64, 0.80, 0.82];
    let mut cells: Vec<(usize, f64, f64)> = Vec::new(); // (c, lambda, rho_nominal)
    for servers in [1usize, 2] {
        for r in rhos {
            cells.push((servers, r * servers as f64 * mu, r));
        }
    }
    let features: Vec<Vec<f64>> = cells
        .iter()
        .map(|&(c, lambda, _)| vec![lambda, c as f64, mu])
        .collect();

    let clustering = cluster::cluster_greedy(&features, 0.05);
    assert_eq!(
        clustering.n_clusters(),
        6,
        "expected representatives at rho 0.60/0.64/0.80 per server count"
    );

    let mut n_extrapolated = 0;
    for cl in &clustering.clusters {
        let (c_r, l_r, rho_r) = cells[cl.representative];
        // simulate ONLY the representative, like the campaign runner does
        let rep = simulate_mmc(c_r, l_r, mu, 0xC1A5, 80_000, 8_000);
        let exact_rep = oracle::mmc(c_r, l_r, mu);
        assert!(
            (rep.rho - exact_rep.rho).abs() / exact_rep.rho < 0.08,
            "rep DES sanity (rho): c={c_r} lambda={l_r}"
        );
        assert!(
            (rep.w - exact_rep.w).abs() / exact_rep.w < 0.08,
            "rep DES sanity (w): c={c_r} lambda={l_r}"
        );

        for &m in &cl.members {
            if m == cl.representative {
                continue;
            }
            n_extrapolated += 1;
            let (c_m, l_m, rho_m) = cells[m];
            assert_eq!(c_m, c_r, "server-count dimension must never merge");
            let d = clustering.assignment[m].distance;
            let bound = cluster::error_bound(d, rho_m);

            // extrapolate exactly like the campaign layer: rescale the
            // representative's measured behaviour by the feature delta
            let rho_est = rep.rho * (l_m / l_r);
            let wq_est = cluster::scale_wait(rep.wq, rho_r, rho_m);
            let w_est = wq_est + (rep.w - rep.wq);

            let truth = oracle::mmc(c_m, l_m, mu);
            let rel = |est: f64, exact: f64| (est - exact).abs() / exact;
            assert!(
                rel(rho_est, truth.rho) <= bound,
                "rho: c={c_m} lambda={l_m}: est {rho_est} vs exact {} (bound {bound})",
                truth.rho
            );
            assert!(
                rel(wq_est, truth.wq) <= bound,
                "wq: c={c_m} lambda={l_m}: est {wq_est} vs exact {} (bound {bound})",
                truth.wq
            );
            assert!(
                rel(w_est, truth.w) <= bound,
                "w: c={c_m} lambda={l_m}: est {w_est} vs exact {} (bound {bound})",
                truth.w
            );
        }
    }
    assert_eq!(n_extrapolated, 4, "two merged cells per server count");
}

#[test]
fn extrapolated_campaign_cells_match_exhaustive_within_the_reported_bound() {
    // near-duplicate fleet loads: the clustered run simulates one and
    // extrapolates the other; the exhaustive run simulates both. The
    // extrapolated cell must agree with its exhaustively-simulated twin
    // to within the error bound it *reports*.
    let campaign = Campaign::new("fleet-acc", 0xACC)
        .variant(VariantConfig::blocking_write())
        .load("dev-a", LoadPattern::steady(60.0, 2.0))
        .load("dev-b", LoadPattern::steady(60.0, 2.01))
        .dataset(
            "tiny",
            DataSetSpec {
                payloads: 6,
                records_per_subsystem: 4,
                bad_rate: 0.0,
                seed: 0,
            },
        );
    let exhaustive = CampaignRunner::new(1).run(&campaign);
    let clustered = CampaignRunner::new(1)
        .with_cluster_tolerance(0.05)
        .run(&campaign);
    let summary = clustered.clustering.as_ref().expect("cluster summary");
    assert_eq!(summary.clusters.len(), 1, "the two loads must merge");

    let mut n_exact = 0;
    let mut n_extrapolated = 0;
    for (cl, ex) in clustered.cells.iter().zip(&exhaustive.cells) {
        match &cl.provenance {
            Some(CellProvenance::Exact { .. }) => {
                n_exact += 1;
                // the representative ran through the ordinary cell path
                assert_eq!(cl.latency_mean_s.to_bits(), ex.latency_mean_s.to_bits());
                assert_eq!(cl.duration_s.to_bits(), ex.duration_s.to_bits());
                assert_eq!(cl.run_cost_usd.to_bits(), ex.run_cost_usd.to_bits());
            }
            Some(CellProvenance::Extrapolated {
                error_bound_rel, ..
            }) => {
                n_extrapolated += 1;
                let bound = *error_bound_rel;
                assert!(bound > 0.0 && bound < 0.5, "bound must be meaningful");
                // structural counts and the rate card are exact
                assert_eq!(cl.zips, ex.zips);
                assert_eq!(cl.files, ex.files);
                assert_eq!(cl.rows, ex.rows);
                assert_eq!(cl.spans_collected, ex.spans_collected);
                assert_eq!(cl.cost_per_hr_usd.to_bits(), ex.cost_per_hr_usd.to_bits());
                // time behaviour is extrapolated — within the bound
                let rel = |a: f64, b: f64| (a - b).abs() / b.abs().max(1e-12);
                for (name, got, want) in [
                    ("latency_mean_s", cl.latency_mean_s, ex.latency_mean_s),
                    ("latency_p50_s", cl.latency_p50_s, ex.latency_p50_s),
                    ("duration_s", cl.duration_s, ex.duration_s),
                    ("throughput_rps", cl.throughput_rps, ex.throughput_rps),
                    ("run_cost_usd", cl.run_cost_usd, ex.run_cost_usd),
                    ("metered_cpu_s", cl.metered_cpu_s, ex.metered_cpu_s),
                ] {
                    assert!(
                        rel(got, want) <= bound,
                        "{name}: extrapolated {got} vs exhaustive {want} \
                         exceeds reported bound {bound}"
                    );
                }
            }
            None => panic!("tolerance > 0 must annotate every cell"),
        }
    }
    assert_eq!((n_exact, n_extrapolated), (1, 1));
}

//! Campaign-runner guarantees, tested across module boundaries:
//! same-seed campaigns replay byte-identically, a 4-cell campaign on
//! 4 threads matches serial execution bit-for-bit, and per-cell
//! telemetry/cost isolation holds.

use plantd::campaign::{Campaign, CampaignRunner};
use plantd::datagen::DataSetSpec;
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;

fn four_cell_campaign(seed: u64) -> Campaign {
    Campaign::new("det-4", seed)
        .variant(VariantConfig::blocking_write())
        .variant(VariantConfig::cpu_limited())
        .load("steady", LoadPattern::steady(6.0, 2.0))
        .load("ramp", LoadPattern::ramp(6.0, 0.0, 4.0))
        .dataset(
            "tiny",
            DataSetSpec {
                payloads: 4,
                records_per_subsystem: 3,
                bad_rate: 0.01,
                seed: 0,
            },
        )
}

#[test]
fn same_seed_campaigns_byte_identical() {
    let a = CampaignRunner::new(4).run(&four_cell_campaign(0xC0FFEE));
    let b = CampaignRunner::new(4).run(&four_cell_campaign(0xC0FFEE));
    let (ja, jb) = (
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
    );
    assert_eq!(ja.as_bytes(), jb.as_bytes(), "same-seed reports must match");
    assert_eq!(a.render(), b.render());
    // and a different seed actually changes the measurements
    let c = CampaignRunner::new(4).run(&four_cell_campaign(0xBEEF));
    assert_ne!(ja, c.to_json().to_string_pretty());
}

#[test]
fn four_cells_on_four_threads_match_serial() {
    let campaign = four_cell_campaign(0x5EED);
    assert_eq!(campaign.n_cells(), 4);
    let parallel = CampaignRunner::new(4).run(&campaign);
    let serial = CampaignRunner::new(1).run(&campaign);
    assert_eq!(parallel.cells.len(), 4);
    assert_eq!(
        parallel.to_json().to_string_pretty().as_bytes(),
        serial.to_json().to_string_pretty().as_bytes(),
        "thread count must not change any cell's numbers"
    );
    // bit-exact on the raw floats, not just the serialized form
    for (p, s) in parallel.cells.iter().zip(&serial.cells) {
        assert_eq!(p.duration_s.to_bits(), s.duration_s.to_bits());
        assert_eq!(p.latency_p99_s.to_bits(), s.latency_p99_s.to_bits());
        assert_eq!(p.metered_cpu_s.to_bits(), s.metered_cpu_s.to_bits());
    }
}

#[test]
fn one_vs_eight_threads_byte_identical() {
    // the sim-kernel regression gate: the same seed must produce
    // byte-identical reports whether cells run serially or on 8 workers
    // (more workers than cells — oversubscription must also be safe)
    let campaign = four_cell_campaign(0x51A7E);
    let serial = CampaignRunner::new(1).run(&campaign);
    let wide = CampaignRunner::new(8).run(&campaign);
    assert_eq!(
        serial.to_json().to_string_pretty().as_bytes(),
        wide.to_json().to_string_pretty().as_bytes(),
        "1-thread and 8-thread reports must be byte-identical"
    );
    assert_eq!(serial.render(), wide.render());
}

#[test]
fn paper_automotive_same_seed_replays_byte_identical() {
    // the acceptance grid itself: Campaign::paper_automotive is the
    // published comparison, so its replay guarantee gets its own gate
    let a = CampaignRunner::new(4).run(&Campaign::paper_automotive(0xD5));
    let b = CampaignRunner::new(2).run(&Campaign::paper_automotive(0xD5));
    assert_eq!(
        a.to_json().to_string_pretty().as_bytes(),
        b.to_json().to_string_pretty().as_bytes(),
    );
}

#[test]
fn burst_load_campaign_is_deterministic_too() {
    // the new burst-style LoadCase through the shared kernel, end to end
    let extended = Campaign::paper_automotive_extended(0xBADCAB);
    assert!(extended.loads.iter().any(|l| l.name == "burst-3x"));
    let small = Campaign::new("burst-det", 0xBADCAB)
        .variant(plantd::pipeline::VariantConfig::blocking_write())
        .load(
            "burst",
            LoadPattern::bursty(30.0, 1.0, 10.0, 2.0, 5.0),
        )
        .dataset(
            "tiny",
            DataSetSpec {
                payloads: 3,
                records_per_subsystem: 2,
                bad_rate: 0.0,
                seed: 0,
            },
        );
    let a = CampaignRunner::new(4).run(&small);
    let b = CampaignRunner::new(1).run(&small);
    assert_eq!(
        a.to_json().to_string_pretty().as_bytes(),
        b.to_json().to_string_pretty().as_bytes(),
    );
    assert!(a.cells[0].zips > 0);
    assert_eq!(a.cells[0].files, a.cells[0].zips * 5);
}

#[test]
fn ranking_is_deterministic_and_complete() {
    let report = CampaignRunner::new(3).run(&four_cell_campaign(0xAB));
    let r1: Vec<String> = report.ranking().iter().map(|c| c.variant.clone()).collect();
    let r2: Vec<String> = report.ranking().iter().map(|c| c.variant.clone()).collect();
    assert_eq!(r1, r2);
    assert_eq!(r1.len(), 4);
    // economics: cpu-limited is the cheapest per record under light load
    // only when it keeps up; under these loads the ranking must at least
    // place every cell (no NaN-induced drops)
    for c in report.ranking() {
        assert!(c.records_per_dollar().is_finite());
    }
}

#[test]
fn cells_are_isolated() {
    // every cell carries its own span count and cost meter; no
    // cross-cell bleed (sums match per-cell recomputation)
    let report = CampaignRunner::new(4).run(&four_cell_campaign(0x77));
    for c in &report.cells {
        assert_eq!(c.spans_collected, c.zips + 2 * c.files);
        assert!(c.metered_cpu_s > 0.0);
        assert!(c.run_cost_usd > 0.0);
    }
    // the two variants saw identical datasets per column: row counts agree
    for load in ["steady", "ramp"] {
        let col: Vec<_> = report.cells.iter().filter(|c| c.load == load).collect();
        assert_eq!(col.len(), 2);
        assert_eq!(col[0].rows, col[1].rows, "load column {load}");
    }
}

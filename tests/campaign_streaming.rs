//! Streaming-grid pins (PR 9): no executor may materialize the whole
//! campaign grid.
//!
//! `CellSpec` lifetimes are counted process-wide by
//! `campaign::alloc_stats` (an RAII token inside every spec), so the
//! high-water mark directly measures how many specs an execution path
//! held alive at once. The lazy `CellGrid` contract is that the peak
//! tracks the *worker count*, not the grid size — on the local thread
//! pool, on the clustered path, and on the fleet driver/worker pair
//! (which shares this process via the loopback worker).
//!
//! Everything runs inside one `#[test]` because the counters are
//! process-global: parallel tests in this binary would smear each
//! other's peaks.

use plantd::campaign::{alloc_stats, Campaign, CampaignRunner};
use plantd::datagen::DataSetSpec;
use plantd::dist::driver::FleetClient;
use plantd::dist::worker::spawn_local;
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;

/// 2 preset variants × 25 near-duplicate loads × 2 datasets = 100 cells,
/// each tiny (≤ 4 sends) so the whole grid simulates in well under a
/// second. Loads are near-duplicates so the clustered path actually
/// merges them.
fn hundred_cell_campaign(seed: u64) -> Campaign {
    let mut c = Campaign::new("streaming-pin", seed)
        .variant(VariantConfig::blocking_write())
        .variant(VariantConfig::cpu_limited());
    for i in 0..25 {
        c = c.load(
            &format!("l{i:02}"),
            LoadPattern::steady(2.0, 1.5 + i as f64 * 0.01),
        );
    }
    c.dataset(
        "tiny-a",
        DataSetSpec {
            payloads: 2,
            records_per_subsystem: 2,
            bad_rate: 0.01,
            seed: 0,
        },
    )
    .dataset(
        "tiny-b",
        DataSetSpec {
            payloads: 3,
            records_per_subsystem: 2,
            bad_rate: 0.01,
            seed: 0,
        },
    )
}

/// Run `f`, returning `(peak specs alive, f's result)` measured from a
/// fresh high-water mark.
fn measured<R>(f: impl FnOnce() -> R) -> (usize, R) {
    let floor = alloc_stats::live();
    alloc_stats::reset_peak();
    let r = f();
    let peak = alloc_stats::peak() - floor;
    (peak, r)
}

#[test]
fn no_execution_path_materializes_the_grid() {
    let campaign = hundred_cell_campaign(0x57A);
    let n = campaign.n_cells();
    assert_eq!(n, 100);
    let threads = 4;
    // generous slack over the thread count: transient clones inside a
    // cell run (plus the loop's own scratch spec) — the pin is that the
    // peak scales with workers, nowhere near the 100-cell grid
    let budget = threads + 8;

    // exhaustive local thread pool
    let (peak, exhaustive) =
        measured(|| CampaignRunner::new(threads).run(&campaign));
    assert_eq!(exhaustive.cells.len(), n);
    assert!(
        peak <= budget,
        "exhaustive path held {peak} specs alive (budget {budget} for {n} cells)"
    );

    // clustered path: featurization, representative runs, and
    // redistribution must all stream off the grid view
    let (peak, clustered) = measured(|| {
        CampaignRunner::new(threads)
            .with_cluster_tolerance(0.05)
            .run(&campaign)
    });
    assert_eq!(clustered.cells.len(), n);
    assert!(
        clustered.clustering.is_some(),
        "near-duplicate loads must actually cluster"
    );
    assert!(
        peak <= budget,
        "clustered path held {peak} specs alive (budget {budget} for {n} cells)"
    );

    // fleet driver + loopback worker (same process, so the counter sees
    // both sides): the driver ships indices, the worker derives specs
    // shard-by-shard
    let mut worker = spawn_local(threads, None).expect("loopback worker");
    let client = FleetClient::new(vec![worker.endpoint()]).with_shard_cells(8);
    let (peak, dist) = measured(|| client.run_campaign(&campaign, None));
    let dist = dist.expect("distributed run");
    worker.stop();
    assert_eq!(
        dist.to_json().to_string_pretty(),
        exhaustive.to_json().to_string_pretty(),
        "distributed report must stay byte-identical"
    );
    assert!(
        peak <= budget,
        "fleet path held {peak} specs alive (budget {budget} for {n} cells)"
    );
}

//! Distributed campaign execution, tested end to end over real
//! loopback TCP: the fleet report must be **byte-identical** to the
//! serial single-process run at every worker count and shard size,
//! through the clustered path, across a mid-campaign worker kill, for
//! validation cases, and through the Fleet resource kind + controller.

use std::time::Duration;

use plantd::campaign::{Campaign, CampaignRunner};
use plantd::datagen::DataSetSpec;
use plantd::dist::driver::FleetClient;
use plantd::dist::worker::{spawn_local, WorkerHandle};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::resources::controller::Controller;
use plantd::resources::{Kind, Phase, Registry};
use plantd::util::json::Json;
use plantd::validate::suite::{run_case, SuiteReport, ValidationSuite};

/// The same 2×2×1 grid `tests/campaign_determinism.rs` pins for the
/// thread pool — the fleet must meet the exact same bar.
fn four_cell_campaign(seed: u64) -> Campaign {
    Campaign::new("det-4", seed)
        .variant(VariantConfig::blocking_write())
        .variant(VariantConfig::cpu_limited())
        .load("steady", LoadPattern::steady(6.0, 2.0))
        .load("ramp", LoadPattern::ramp(6.0, 0.0, 4.0))
        .dataset(
            "tiny",
            DataSetSpec {
                payloads: 4,
                records_per_subsystem: 3,
                bad_rate: 0.01,
                seed: 0,
            },
        )
}

/// Spawn `n` in-process workers and collect their endpoints.
fn spawn_fleet(n: usize) -> (Vec<WorkerHandle>, Vec<String>) {
    let workers: Vec<WorkerHandle> = (0..n)
        .map(|_| spawn_local(2, None).expect("spawn local worker"))
        .collect();
    let endpoints = workers.iter().map(|w| w.endpoint()).collect();
    (workers, endpoints)
}

fn report_bytes(r: &plantd::campaign::CampaignReport) -> Vec<u8> {
    r.to_json().to_string_pretty().into_bytes()
}

#[test]
fn exhaustive_fleet_report_byte_identical_at_any_worker_count_and_shard_size() {
    let campaign = four_cell_campaign(0x5EED);
    let serial = report_bytes(&CampaignRunner::new(1).run(&campaign));
    // shard 1 (max dealing), shard 3 (uneven split of 4), shard 9
    // (bigger than the whole grid → a single shard)
    for workers in [1usize, 2, 4] {
        for shard in [1usize, 3, 9] {
            let (_fleet, endpoints) = spawn_fleet(workers);
            let report = FleetClient::new(endpoints)
                .with_shard_cells(shard)
                .run_campaign(&campaign, None)
                .unwrap_or_else(|e| panic!("{workers} workers, shard {shard}: {e}"));
            assert_eq!(
                report_bytes(&report),
                serial,
                "{workers} workers, shard {shard}: distributed report must \
                 be byte-identical to the serial run"
            );
        }
    }
}

#[test]
fn clustered_fleet_report_matches_local_clustered_byte_for_byte() {
    let campaign = four_cell_campaign(0xC105);
    // tolerance 0.0: every cell is its own cluster, all four
    // representatives ship with full latency samples. A loose tolerance
    // actually merges cells, exercising redistribution over the wire.
    for tolerance in [0.0, 0.35] {
        let local = CampaignRunner::new(2)
            .with_cluster_tolerance(tolerance)
            .run(&campaign);
        let (_fleet, endpoints) = spawn_fleet(2);
        let dist = FleetClient::new(endpoints)
            .with_shard_cells(1)
            .run_campaign(&campaign, Some(tolerance))
            .unwrap();
        assert_eq!(
            report_bytes(&dist),
            report_bytes(&local),
            "tolerance {tolerance}: clustered fleet run must match the \
             local clustered run byte-for-byte"
        );
    }
}

#[test]
fn worker_killed_mid_campaign_report_unchanged() {
    let campaign = four_cell_campaign(0xDEAD);
    let serial = report_bytes(&CampaignRunner::new(1).run(&campaign));
    // worker A is armed to die on its first shard *after the handshake,
    // without replying* — the driver must requeue that shard on worker
    // B and still merge a byte-identical report
    let doomed = spawn_local(2, Some(0)).unwrap();
    let survivor = spawn_local(2, None).unwrap();
    let endpoints = vec![doomed.endpoint(), survivor.endpoint()];
    let report = FleetClient::new(endpoints)
        .with_shard_cells(1)
        .run_campaign(&campaign, None)
        .expect("the surviving worker must finish the campaign");
    assert_eq!(
        report_bytes(&report),
        serial,
        "losing a worker mid-campaign must not change a single byte"
    );
}

#[test]
fn all_workers_dead_fails_readably() {
    // port 9 (discard) has no listener: connects are refused, shards
    // never run, and the driver reports the loss instead of hanging
    let mut client = FleetClient::new(vec!["127.0.0.1:9".to_string()]);
    client.connect_timeout = Duration::from_millis(300);
    let err = client
        .run_campaign(&four_cell_campaign(1), None)
        .unwrap_err();
    assert!(err.contains("unfilled"), "{err}");
}

#[test]
fn distributed_validation_cases_byte_identical_to_local() {
    let suite = ValidationSuite::queueing();
    // a two-case subset keeps the test inside a sane wall-clock budget;
    // index order is intentionally not grid order
    let picks = [3usize, 4];
    let local = SuiteReport {
        suite: suite.name.clone(),
        results: picks.iter().map(|&i| run_case(&suite.cases[i])).collect(),
    };
    let (_fleet, endpoints) = spawn_fleet(2);
    let dist = FleetClient::new(endpoints)
        .run_queueing_cases(&picks)
        .unwrap();
    assert_eq!(
        dist.to_json().to_string_pretty().as_bytes(),
        local.to_json().to_string_pretty().as_bytes(),
        "distributed validation cases must match local execution"
    );
    // index validation happens before any network traffic
    let lonely = FleetClient::new(vec!["127.0.0.1:9".to_string()]);
    assert!(lonely.run_queueing_cases(&[99]).unwrap_err().contains("out of range"));
    assert!(lonely.run_queueing_cases(&[1, 1]).unwrap_err().contains("twice"));
}

#[test]
fn fleet_resource_and_fleet_campaign_run_through_controller() {
    let (_fleet, endpoints) = spawn_fleet(2);
    let manifest = format!(
        r#"{{"resources": [
            {{"kind": "Fleet", "name": "lab",
             "spec": {{"shard_cells": 3, "workers": [
                 {{"name": "a", "addr": "{0}"}},
                 {{"name": "b", "addr": "{1}"}}]}}}},
            {{"kind": "Experiment", "name": "sweep",
             "spec": {{"campaign": {{"grid": "paper", "seed": 7,
                                     "threads": 2, "fleet": "lab"}}}}}}
        ]}}"#,
        endpoints[0], endpoints[1]
    );
    let c = Controller::new(Registry::new());
    c.apply_manifest(&Json::parse(&manifest).unwrap()).unwrap();
    c.reconcile();
    for (kind, name) in [(Kind::Fleet, "lab"), (Kind::Experiment, "sweep")] {
        let r = c.registry().get(kind, name).unwrap();
        assert_eq!(r.phase, Phase::Ready, "{}/{name}: {:?}", kind.as_str(), r.conditions);
    }

    // running the Fleet health-checks every declared worker
    let out = c.run(Kind::Fleet, "lab").unwrap().output;
    assert!(out.contains("2/2 worker(s) healthy"), "{out}");
    assert!(out.contains("worker 'a'"), "{out}");
    let lab = c.registry().get(Kind::Fleet, "lab").unwrap();
    assert_eq!(lab.phase, Phase::Completed);
    assert_eq!(lab.status.get("healthy").and_then(Json::as_u64), Some(2));

    // the fleet-referencing campaign reproduces the local report
    // byte-for-byte (same comparison tests/resource_api.rs makes for
    // the thread-pool path)
    let out = c.run(Kind::Experiment, "sweep").unwrap().output;
    let direct = CampaignRunner::new(2).run(&Campaign::paper_automotive(7));
    assert_eq!(
        out,
        format!("{}\n", direct.render()),
        "fleet execution through the controller must reproduce the \
         direct campaign report byte-for-byte"
    );
    let sweep = c.registry().get(Kind::Experiment, "sweep").unwrap();
    assert_eq!(sweep.phase, Phase::Completed);
    assert_eq!(sweep.status.get_str("fleet"), Some("lab"));
}

#[test]
fn dead_fleet_fails_at_run_time_not_apply_time() {
    // Fleet specs validate shape only — a fleet whose workers are not
    // up yet must still reconcile Ready (declare first, start later)...
    let c = Controller::new(Registry::new());
    c.apply_manifest(
        &Json::parse(
            r#"{"resources": [
                {"kind": "Fleet", "name": "ghost",
                 "spec": {"workers": [{"name": "w", "addr": "127.0.0.1:9"}]}},
                {"kind": "Experiment", "name": "sweep",
                 "spec": {"campaign": {"grid": "paper", "seed": 7,
                                       "fleet": "ghost"}}}
            ]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    c.reconcile();
    assert_eq!(
        c.registry().get(Kind::Fleet, "ghost").unwrap().phase,
        Phase::Ready,
        "fleet shape validation must not require live workers"
    );
    // ...but running it reports the dead workers, with the fix in hand
    let err = c.run(Kind::Fleet, "ghost").unwrap_err();
    assert!(err.contains("plantd worker"), "{err}");
    // and a campaign pointed at the dead fleet fails readably too
    let err = c.run(Kind::Experiment, "sweep").unwrap_err();
    assert!(err.contains("worker") || err.contains("unfilled"), "{err}");
}

//! Protocol-hardening tests for the fleet wire format (`plantd::dist`):
//! frame round-trips under randomized payloads, framing rejections
//! (empty, truncated, over-limit), bit-exact scalar codecs, message and
//! campaign codec round-trips, and live-worker failure containment — a
//! bad handshake or a garbage frame must never take a worker down.

use std::io::{Cursor, Read, Write};
use std::net::TcpStream;
use std::time::Duration;

use plantd::campaign::Campaign;
use plantd::datagen::DataSetSpec;
use plantd::dist::proto::{
    self, read_frame, recv_msg, send_msg, write_frame, Msg, RecvError, MAX_FRAME, PROTO_VERSION,
};
use plantd::dist::{driver, worker};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;

/// Deterministic 64-bit LCG (Knuth MMIX constants) — enough entropy for
/// property-style payload generation without any external crate.
struct Lcg(u64);

impl Lcg {
    fn next(&mut self) -> u64 {
        self.0 = self
            .0
            .wrapping_mul(6364136223846793005)
            .wrapping_add(1442695040888963407);
        self.0
    }
}

/// One variant × one load × one dataset: the smallest real campaign,
/// cheap enough to execute inside a protocol test.
fn tiny_campaign(seed: u64) -> Campaign {
    Campaign::new("proto-tiny", seed)
        .variant(VariantConfig::blocking_write())
        .load("steady", LoadPattern::steady(4.0, 1.0))
        .dataset(
            "tiny",
            DataSetSpec {
                payloads: 2,
                records_per_subsystem: 2,
                bad_rate: 0.01,
                seed: 0,
            },
        )
}

/// Connect to a worker endpoint with test-friendly timeouts.
fn connect(endpoint: &str) -> TcpStream {
    let stream = TcpStream::connect(endpoint).expect("connect to local worker");
    stream
        .set_read_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    stream
        .set_write_timeout(Some(Duration::from_secs(60)))
        .unwrap();
    stream
}

/// Complete a well-formed v1 handshake on a fresh stream.
fn handshake(stream: &mut TcpStream) {
    send_msg(
        stream,
        &Msg::Hello {
            version: PROTO_VERSION,
        },
    )
    .unwrap();
    match recv_msg(stream).expect("handshake reply") {
        Msg::Ack { version } => assert_eq!(version, PROTO_VERSION),
        other => panic!("expected ack, got '{}'", other.type_name()),
    }
}

// ---------------------------------------------------------------------------
// framing
// ---------------------------------------------------------------------------

#[test]
fn frames_round_trip_randomized_payloads() {
    let mut rng = Lcg(0xF4A3_E001);
    for _ in 0..200 {
        let len = 1 + (rng.next() as usize % 4096);
        let payload: Vec<u8> = (0..len).map(|_| rng.next() as u8).collect();
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        assert_eq!(buf.len(), 4 + len, "length prefix + payload, nothing else");
        let back = read_frame(&mut Cursor::new(&buf)).unwrap();
        assert_eq!(back, payload);
    }
    // boundary sizes: one byte, and exactly MAX_FRAME
    for len in [1usize, MAX_FRAME] {
        let payload = vec![0xA5u8; len];
        let mut buf = Vec::new();
        write_frame(&mut buf, &payload).unwrap();
        assert_eq!(read_frame(&mut Cursor::new(&buf)).unwrap(), payload);
    }
}

#[test]
fn back_to_back_frames_keep_their_boundaries() {
    let mut rng = Lcg(0xBEEF);
    let payloads: Vec<Vec<u8>> = (0..16)
        .map(|_| {
            let len = 1 + (rng.next() as usize % 512);
            (0..len).map(|_| rng.next() as u8).collect()
        })
        .collect();
    let mut buf = Vec::new();
    for p in &payloads {
        write_frame(&mut buf, p).unwrap();
    }
    let mut cursor = Cursor::new(&buf);
    for p in &payloads {
        assert_eq!(&read_frame(&mut cursor).unwrap(), p);
    }
    // and the stream is fully consumed
    let mut rest = Vec::new();
    cursor.read_to_end(&mut rest).unwrap();
    assert!(rest.is_empty());
}

#[test]
fn framing_rejects_empty_truncated_and_oversized() {
    // empty payloads are refused at the sender
    let mut buf = Vec::new();
    assert!(write_frame(&mut buf, &[]).is_err());
    // and a zero length prefix is refused at the receiver
    assert!(read_frame(&mut Cursor::new(&[0u8, 0, 0, 0])).is_err());
    // over-limit payloads are refused at the sender...
    let big = vec![0u8; MAX_FRAME + 1];
    assert!(write_frame(&mut Vec::new(), &big).is_err());
    // ...and an over-limit length prefix is refused before allocation
    // (u32::MAX would be a 4 GiB allocation if it were honored)
    let huge = u32::MAX.to_be_bytes();
    assert!(read_frame(&mut Cursor::new(&huge)).is_err());
    // truncated payload: prefix promises 100 bytes, stream has 10
    let mut truncated = Vec::new();
    truncated.extend_from_slice(&100u32.to_be_bytes());
    truncated.extend_from_slice(&[7u8; 10]);
    assert!(read_frame(&mut Cursor::new(&truncated)).is_err());
    // truncated length prefix
    assert!(read_frame(&mut Cursor::new(&[0u8, 0])).is_err());
}

#[test]
fn recv_classifies_frame_vs_decode_errors() {
    // broken framing → Frame (close the connection)
    let mut eof = Cursor::new(Vec::<u8>::new());
    assert!(matches!(recv_msg(&mut eof), Err(RecvError::Frame(_))));
    // sound frame, garbage payload → Decode (reply Err, keep serving)
    let mut buf = Vec::new();
    write_frame(&mut buf, b"this is not json").unwrap();
    assert!(matches!(
        recv_msg(&mut Cursor::new(&buf)),
        Err(RecvError::Decode(_))
    ));
    // valid JSON that is not a message is also Decode-class
    let mut buf = Vec::new();
    write_frame(&mut buf, br#"{"type": "warp-drive"}"#).unwrap();
    assert!(matches!(
        recv_msg(&mut Cursor::new(&buf)),
        Err(RecvError::Decode(_))
    ));
    // non-UTF-8 payload too
    let mut buf = Vec::new();
    write_frame(&mut buf, &[0xFF, 0xFE, 0x80]).unwrap();
    assert!(matches!(
        recv_msg(&mut Cursor::new(&buf)),
        Err(RecvError::Decode(_))
    ));
}

// ---------------------------------------------------------------------------
// codecs
// ---------------------------------------------------------------------------

#[test]
fn scalar_codecs_are_bit_exact() {
    let specials = [
        0.0,
        -0.0,
        f64::NAN,
        f64::INFINITY,
        f64::NEG_INFINITY,
        f64::MIN_POSITIVE,
        f64::MIN_POSITIVE / 4.0, // subnormal
        f64::MAX,
        0.1, // classic non-exact decimal
    ];
    for &x in &specials {
        let back = proto::f64_from_wire(&proto::f64_to_wire(x)).unwrap();
        assert_eq!(back.to_bits(), x.to_bits(), "f64 {x} must survive the wire");
    }
    let mut rng = Lcg(0xD00D);
    for _ in 0..500 {
        let bits = rng.next();
        let x = f64::from_bits(bits);
        let back = proto::f64_from_wire(&proto::f64_to_wire(x)).unwrap();
        assert_eq!(back.to_bits(), bits);
        let v = rng.next();
        assert_eq!(proto::u64_from_wire(&proto::u64_to_wire(v)).unwrap(), v);
    }
    // the wire form must never fall back to lossy JSON numbers
    assert!(proto::f64_from_wire(&plantd::util::json::Json::num(1.5)).is_err());
    assert!(proto::u64_from_wire(&plantd::util::json::Json::num(7)).is_err());
}

#[test]
fn messages_round_trip_through_json() {
    let msgs = vec![
        Msg::Hello { version: 1 },
        Msg::Ack { version: 1 },
        Msg::RunCells {
            campaign: tiny_campaign(0xC0DE),
            cells: vec![0, 2, 5],
            full: true,
        },
        Msg::RunValidation { cases: vec![1, 3] },
        Msg::Shutdown,
        Msg::Err {
            msg: "something broke".to_string(),
        },
    ];
    for m in &msgs {
        let j = m.to_json();
        let back = Msg::from_json(&j).unwrap();
        assert_eq!(
            back.to_json().to_string_compact(),
            j.to_string_compact(),
            "'{}' must round-trip canonically",
            m.type_name()
        );
    }
}

#[test]
fn campaign_codec_round_trips_and_validates() {
    let c = tiny_campaign(0xABCD_EF01);
    let wire = proto::campaign_to_wire(&c);
    let back = proto::campaign_from_wire(&wire).unwrap();
    // canonical form is a fixed point — this is what the worker's
    // per-connection cache keys on
    assert_eq!(
        proto::campaign_to_wire(&back).to_string_compact(),
        wire.to_string_compact()
    );
    // and the decoded campaign derives the identical grid
    assert_eq!(back.n_cells(), c.n_cells());
    let (a, b): (Vec<_>, Vec<_>) = (c.cells(), back.cells());
    for (sa, sb) in a.iter().zip(&b) {
        assert_eq!(sa.seed, sb.seed, "per-cell seeds must survive the wire");
    }
    // unknown variant names are refused at decode time, not at run time
    let mut j = wire.to_string_compact();
    j = j.replace("blocking-write", "imaginary-variant");
    let bad = plantd::util::json::Json::parse(&j).unwrap();
    assert!(proto::campaign_from_wire(&bad).is_err());
}

// ---------------------------------------------------------------------------
// live worker: failure containment
// ---------------------------------------------------------------------------

#[test]
fn bad_version_handshake_is_refused_and_worker_survives() {
    let w = worker::spawn_local(2, None).unwrap();
    let mut stream = connect(&w.endpoint());
    send_msg(&mut stream, &Msg::Hello { version: 999 }).unwrap();
    match recv_msg(&mut stream).expect("refusal reply") {
        Msg::Err { msg } => assert!(msg.contains("version"), "{msg}"),
        other => panic!("expected err, got '{}'", other.type_name()),
    }
    // the worker refused that connection but is still serving: a
    // well-formed handshake on a fresh connection succeeds
    let mut stream2 = connect(&w.endpoint());
    handshake(&mut stream2);
}

#[test]
fn first_message_must_be_hello() {
    let w = worker::spawn_local(2, None).unwrap();
    let mut stream = connect(&w.endpoint());
    send_msg(&mut stream, &Msg::Shutdown).unwrap();
    match recv_msg(&mut stream).expect("refusal reply") {
        Msg::Err { msg } => assert!(msg.contains("hello"), "{msg}"),
        other => panic!("expected err, got '{}'", other.type_name()),
    }
    // a shutdown sent before the handshake must NOT stop the worker
    let mut stream2 = connect(&w.endpoint());
    handshake(&mut stream2);
}

#[test]
fn garbage_frame_gets_err_reply_and_connection_keeps_serving() {
    let w = worker::spawn_local(2, None).unwrap();
    let mut stream = connect(&w.endpoint());
    handshake(&mut stream);

    // garbage JSON in a sound frame: Err reply, connection stays up
    write_frame(&mut stream, b"{{{{ not json").unwrap();
    assert!(matches!(
        recv_msg(&mut stream).expect("err reply"),
        Msg::Err { .. }
    ));

    // out-of-range cell index: Err reply, connection stays up
    send_msg(
        &mut stream,
        &Msg::RunCells {
            campaign: tiny_campaign(0x11),
            cells: vec![99],
            full: false,
        },
    )
    .unwrap();
    match recv_msg(&mut stream).expect("err reply") {
        Msg::Err { msg } => assert!(msg.contains("out of range"), "{msg}"),
        other => panic!("expected err, got '{}'", other.type_name()),
    }

    // the SAME connection then serves a real shard: the worker never
    // panicked, never closed, never wedged
    send_msg(
        &mut stream,
        &Msg::RunCells {
            campaign: tiny_campaign(0x11),
            cells: vec![0],
            full: false,
        },
    )
    .unwrap();
    match recv_msg(&mut stream).expect("cell results") {
        Msg::CellResults { cells } => {
            assert_eq!(cells.len(), 1);
            assert_eq!(cells[0].index, 0);
        }
        other => panic!("expected cell_results, got '{}'", other.type_name()),
    }
}

#[test]
fn oversized_frame_closes_only_the_offending_connection() {
    let w = worker::spawn_local(2, None).unwrap();
    let mut stream = connect(&w.endpoint());
    handshake(&mut stream);
    // an over-limit length prefix is a framing violation: the worker
    // closes this connection without reading the (never-sent) body
    let lie = ((MAX_FRAME as u32) + 1).to_be_bytes();
    stream.write_all(&lie).unwrap();
    stream.flush().unwrap();
    assert!(
        matches!(recv_msg(&mut stream), Err(RecvError::Frame(_))),
        "worker must hang up on a framing violation"
    );
    // but the accept loop is untouched
    let mut stream2 = connect(&w.endpoint());
    handshake(&mut stream2);
}

#[test]
fn shutdown_is_acked_and_stops_the_worker() {
    let w = worker::spawn_local(2, None).unwrap();
    let endpoint = w.endpoint();
    driver::shutdown(&endpoint, Duration::from_secs(10)).unwrap();
    // the listener is gone (give the accept loop a beat to observe the
    // stop flag; the self-connect nudge makes this prompt)
    let mut dead = false;
    for _ in 0..50 {
        match TcpStream::connect(&endpoint) {
            Err(_) => {
                dead = true;
                break;
            }
            Ok(s) => {
                // a racing accept may still take one connection; a
                // closed-without-handshake stream also proves shutdown
                drop(s);
                std::thread::sleep(Duration::from_millis(20));
            }
        }
    }
    assert!(dead, "worker must stop listening after shutdown");
}

//! Golden-snapshot regression: canonical reports under `tests/golden/`
//! must match byte-for-byte. `oracle_closed_form.json` is committed and
//! always strictly compared (it is pure rational arithmetic — identical
//! bytes on every IEEE-754 platform). The DES-derived subjects bootstrap
//! on first run (written with a double-generation determinism proof and
//! an eprintln asking for a commit) and are strictly compared once the
//! files exist — committing them is what turns the harness into a
//! regression bar, see docs/VALIDATION.md.

use std::path::PathBuf;

use plantd::validate::{snapshot, SnapshotMode, SnapshotStatus};

fn golden_dir() -> PathBuf {
    // tests run with the crate root (rust/) as cwd; golden files live at
    // the repo root next to the tests themselves
    PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../tests/golden")
}

/// The committed analytic snapshot never bootstraps: a missing or
/// drifting file is a hard failure. If this fires, either the oracle's
/// closed forms changed (update the snapshot deliberately, with a PR
/// note) or a refactor moved its arithmetic (fix the refactor).
#[test]
fn committed_oracle_snapshot_matches_exactly() {
    let subjects = snapshot::subjects();
    let oracle = subjects
        .iter()
        .find(|s| s.name == "oracle-closed-form")
        .expect("oracle subject registered");
    let path = golden_dir().join(oracle.file);
    let golden = std::fs::read_to_string(&path).unwrap_or_else(|e| {
        panic!(
            "{} must be committed (it is platform-independent): {e}",
            path.display()
        )
    });
    let generated = snapshot::render_subject(oracle);
    assert_eq!(
        golden, generated,
        "the analytic oracle's closed forms moved; regenerate with \
         `plantd validate --suite snapshots --update` only if the change \
         is intended, and say why in the PR"
    );
}

/// Every subject, through the real harness in bootstrap mode: existing
/// files are strictly compared (drift fails), missing ones are written
/// after a double-generation determinism proof.
#[test]
fn all_snapshots_match_or_bootstrap() {
    let outcomes = snapshot::check(&golden_dir(), SnapshotMode::BootstrapMissing);
    let mut bootstrapped = Vec::new();
    for o in &outcomes {
        match &o.status {
            SnapshotStatus::Match => {}
            SnapshotStatus::Bootstrapped => bootstrapped.push(o.path.display().to_string()),
            other => panic!("{}: {}", o.name, other.label()),
        }
    }
    if !bootstrapped.is_empty() {
        eprintln!(
            "bootstrapped {} golden snapshot(s) — commit them to arm the \
             regression bar:\n  {}",
            bootstrapped.len(),
            bootstrapped.join("\n  ")
        );
    }
}

/// The DES-derived subjects regenerate byte-identically within a
/// process — the determinism the `--update` workflow relies on.
#[test]
fn snapshot_generation_is_deterministic() {
    for s in snapshot::subjects() {
        if s.name == "campaign-paper" || s.name == "experiment-sim" {
            // covered (more cheaply) by tests/campaign_determinism.rs and
            // the controller determinism test; regenerating them twice
            // here would double the most expensive subjects
            continue;
        }
        let a = snapshot::render_subject(&s);
        let b = snapshot::render_subject(&s);
        assert_eq!(a, b, "subject '{}' is not deterministic", s.name);
    }
}

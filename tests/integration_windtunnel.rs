//! Integration tests: the full wind-tunnel loop across module boundaries —
//! datagen → loadgen → pipeline → telemetry → cost → experiment → twin →
//! traffic → bizsim — plus PJRT-vs-native cross-validation when the AOT
//! artifacts are present.

use std::path::Path;

use plantd::bizsim::{monthly_costs, simulate_batch, CostSpec, SloSpec};
use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::runtime::{native::NativeBackend, Engine, ScenarioParams, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::twin::{TwinKind, TwinParams};

fn small_exp() -> Experiment {
    Experiment::new(
        "integration",
        LoadPattern::ramp(10.0, 0.0, 8.0), // 40 zips
        DataSet::generate(DataSetSpec {
            payloads: 16,
            records_per_subsystem: 5,
            bad_rate: 0.05,
            seed: 0xBEEF,
        }),
    )
}

#[test]
fn measure_fit_simulate_roundtrip() {
    // the core loop: measure a pipeline, fit its twin, simulate a year
    let harness = ExperimentHarness::new(300.0);
    let rec = harness
        .run(&VariantConfig::no_blocking_write(), &small_exp())
        .unwrap();
    assert_eq!(rec.zips_sent, 40);
    assert!(rec.rows_inserted > 0);
    assert!(rec.rows_scrubbed > 0, "5% bad rate must scrub something");

    let twin = TwinParams::fit(&rec);
    assert_eq!(twin.kind, TwinKind::Simple);
    assert!(twin.max_rps > 0.5);

    let result = simulate_batch(
        &NativeBackend,
        &[twin],
        &TrafficModel::nominal(),
        &SloSpec::default(),
    )
    .unwrap();
    assert_eq!(result.len(), 1);
    assert!(result[0].cost_usd > 0.0);
    // conservation through the whole stack
    let total_load: f64 = result[0].load.iter().sum();
    let processed: f64 = result[0].throughput.iter().sum();
    let backlog = result[0].queue.last().unwrap();
    assert!(((processed + backlog) - total_load).abs() / total_load < 1e-6);
}

#[test]
fn spans_flow_to_tsdb_and_cost_is_prorated() {
    let harness = ExperimentHarness::new(300.0);
    let rec = harness
        .run(&VariantConfig::blocking_write(), &small_exp())
        .unwrap();
    // spans landed as metrics
    // the [started_s, drained_s] window is inclusive and sufficient: no
    // span ends after the drain timestamp, so no fudge term is needed
    let recs = harness.tsdb.sum_range(
        "stage_records",
        &[("stage", "unzipper_phase")],
        rec.started_s,
        rec.drained_s,
    );
    assert_eq!(recs as u64, 40);
    // v2x file-level records = 5x zips (the paper's Fig. 8 note)
    let v2x = harness.tsdb.sum_range(
        "stage_records",
        &[("stage", "v2x_phase")],
        rec.started_s,
        rec.drained_s,
    );
    assert_eq!(v2x as u64, 200);
    // cost = rate x prorated duration, not whole billing hours
    let expect = rec.cost_per_hr_usd * rec.duration_s / 3600.0;
    assert!((rec.total_cost_usd - expect).abs() < 1e-12);
    assert!(rec.duration_s < 3600.0, "short experiment must not bill a whole hour");
}

#[test]
fn blocking_defect_visible_in_blob_and_latency() {
    // the paper's §VII.A observation, as an assertion: removing the
    // blocking write raises throughput and drops v2x latency
    let harness = ExperimentHarness::new(300.0);
    let exp = small_exp();
    let block = harness.run(&VariantConfig::blocking_write(), &exp).unwrap();
    let noblock = harness
        .run(&VariantConfig::no_blocking_write(), &exp)
        .unwrap();
    assert!(noblock.mean_throughput_rps > block.mean_throughput_rps * 1.5);
    assert!(noblock.latency_nq_mean_s < block.latency_nq_mean_s);
    // both persisted the same number of blob objects eventually
    // (40 raw zips + 200 parquet files each)
}

#[test]
fn engaged_pipeline_refuses_second_experiment() {
    // PlantD "will not start another experiment until the first one is
    // done" — the engage flag is the mechanism
    let harness = ExperimentHarness::new(2000.0);
    let cloud = &harness.cloud;
    let spans = plantd::telemetry::SpanSink::new();
    let handle = plantd::pipeline::PipelineDeployment::deploy(
        &VariantConfig::blocking_write(),
        cloud,
        "wind-tunnel-node",
        harness.clock.clone(),
        spans,
    );
    assert!(handle.engage());
    assert!(!handle.engage(), "second engage must be refused");
    handle.release();
    assert!(handle.engage());
    handle.finish();
}

#[test]
fn pjrt_and_native_backends_agree_end_to_end() {
    let Ok(engine) = Engine::load(Path::new("artifacts")) else {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return;
    };
    let native = NativeBackend;
    let model = TrafficModel::nominal();

    // traffic
    let a = engine.traffic(&model).unwrap();
    let b = native.traffic(&model).unwrap();
    assert_eq!(a.len(), b.len());
    for (x, y) in a.iter().zip(&b) {
        assert!((x - y).abs() / y.max(1.0) < 1e-4, "traffic diverged: {x} vs {y}");
    }

    // twin_sim
    let scenarios = [
        ScenarioParams { cap_rps: 1.95, base_latency_s: 0.15 },
        ScenarioParams { cap_rps: 0.66, base_latency_s: 0.29 },
    ];
    let pa = engine.twin_sim(&model, &scenarios).unwrap();
    let pb = native.twin_sim(&model, &scenarios).unwrap();
    for s in 0..2 {
        for t in (0..8760).step_by(97) {
            let (x, y) = (pa.queue[s][t], pb.queue[s][t]);
            let tol = 1e-3 * y.abs().max(1000.0);
            assert!((x - y).abs() < tol, "queue[{s}][{t}]: {x} vs {y}");
        }
        // throughput conservation holds on both backends
        let (ta, tb): (f64, f64) = (
            pa.throughput[s].iter().sum(),
            pb.throughput[s].iter().sum(),
        );
        assert!((ta - tb).abs() / tb < 1e-3);
    }

    // retention
    let daily: Vec<f64> = (0..365).map(|d| 1.0 + (d % 7) as f64 * 0.3).collect();
    let ra = engine.retention(&daily, 91.0).unwrap();
    let rb = native.retention(&daily, 91.0).unwrap();
    for (x, y) in ra.iter().zip(&rb) {
        assert!((x - y).abs() < 0.05, "retention diverged: {x} vs {y}");
    }
}

#[test]
fn monthly_costs_consistent_across_backends() {
    let Ok(engine) = Engine::load(Path::new("artifacts")) else {
        return;
    };
    let native = NativeBackend;
    let load = native.traffic(&TrafficModel::nominal()).unwrap();
    let spec = CostSpec::default();
    let a = monthly_costs(&engine, &load, 0.0703, &spec).unwrap();
    let b = monthly_costs(&native, &load, 0.0703, &spec).unwrap();
    for (x, y) in a.iter().zip(&b) {
        assert!((x.storage - y.storage).abs() < 0.05);
        assert_eq!(x.cloud, y.cloud);
    }
}

#[test]
fn resource_registry_drives_an_experiment() {
    // declarative path: register resources, reconcile, then execute the
    // experiment the registry describes
    use plantd::resources::{Kind, Phase, Registry};
    use plantd::util::json::Json;

    let reg = Registry::new();
    reg.apply(Kind::Schema, "telematics", Json::parse(r#"{"fields":[]}"#).unwrap());
    reg.apply(Kind::DataSet, "fleet", Json::parse(r#"{"schema":"telematics"}"#).unwrap());
    reg.apply(
        Kind::LoadPattern,
        "ramp",
        Json::parse(r#"{"segments":[{"duration_s":10,"start_rps":0,"end_rps":8}]}"#).unwrap(),
    );
    reg.apply(
        Kind::Pipeline,
        "no-blocking-write",
        Json::parse(r#"{"variant":"no-blocking-write"}"#).unwrap(),
    );
    reg.apply(
        Kind::Experiment,
        "e2e",
        Json::parse(r#"{"dataset":"fleet","load_pattern":"ramp","pipeline":"no-blocking-write"}"#)
            .unwrap(),
    );
    reg.reconcile();
    let exp_res = reg.get(Kind::Experiment, "e2e").unwrap();
    assert_eq!(exp_res.phase, Phase::Ready);

    // materialize and run
    let pattern = LoadPattern::from_json(
        &reg.get(Kind::LoadPattern, "ramp").unwrap().spec,
    )
    .unwrap();
    let harness = ExperimentHarness::new(500.0);
    reg.set_phase(Kind::Pipeline, "no-blocking-write", Phase::Engaged, "e2e started");
    let rec = harness
        .run(
            &VariantConfig::no_blocking_write(),
            &Experiment::new("e2e", pattern, small_exp().dataset),
        )
        .unwrap();
    reg.set_phase(Kind::Pipeline, "no-blocking-write", Phase::Ready, "e2e finished");
    reg.set_phase(Kind::Experiment, "e2e", Phase::Completed, "drained");
    assert_eq!(rec.zips_sent, 40);
    assert_eq!(
        reg.get(Kind::Experiment, "e2e").unwrap().phase,
        Phase::Completed
    );
}

#[test]
fn table2_headline_crossover_from_freshly_fitted_twins() {
    // fit twins from (fast, reduced) experiments, then check the paper's
    // headline: non-block meets SLO everywhere, cpu-limited never does
    let harness = ExperimentHarness::new(300.0);
    let exp = Experiment::new(
        "fit",
        LoadPattern::steady(8.0, 6.0), // 48 zips, saturating
        small_exp().dataset,
    );
    let mut twins = Vec::new();
    for cfg in [
        VariantConfig::no_blocking_write(),
        VariantConfig::cpu_limited(),
    ] {
        let rec = harness.run(&cfg, &exp).unwrap();
        twins.push(TwinParams::fit(&rec));
    }
    let results = simulate_batch(
        &NativeBackend,
        &twins,
        &TrafficModel::nominal(),
        &SloSpec::default(),
    )
    .unwrap();
    assert!(results[0].slo_met, "no-blocking should meet the SLO");
    assert!(!results[1].slo_met, "cpu-limited should collapse");
    assert!(results[1].backlog_latency_s > 30.0 * 86_400.0);
}

#[test]
fn query_load_measures_warehouse_latency() {
    let harness = ExperimentHarness::new(500.0);
    let mut exp = small_exp();
    exp.queries = Some(plantd::experiment::QueryLoad {
        rate_qps: 5.0,
        duration_s: 4.0,
    });
    let rec = harness
        .run(&VariantConfig::no_blocking_write(), &exp)
        .unwrap();
    let p50 = rec.query_p50_s.expect("query stats present");
    let p95 = rec.query_p95_s.unwrap();
    let qps = rec.query_achieved_qps.unwrap();
    assert!(p50 > 0.0 && p95 >= p50, "p50={p50} p95={p95}");
    // 2 ms planning + ~1 µs/row over ~5k rows → ~7 ms/query
    assert!(p50 < 1.0, "query latency implausible: {p50}");
    assert!((qps - 5.0).abs() / 5.0 < 0.5, "qps {qps}");
}

#[test]
fn scheduled_experiment_waits_for_start_time() {
    let harness = ExperimentHarness::new(2000.0);
    let mut exp = Experiment::new(
        "scheduled",
        LoadPattern::steady(2.0, 2.0),
        small_exp().dataset,
    );
    let start_at = harness.clock.now_s() + 20.0;
    exp.start_at_s = Some(start_at);
    let rec = harness
        .run(&VariantConfig::no_blocking_write(), &exp)
        .unwrap();
    assert!(
        rec.started_s >= start_at - 1.0,
        "started {} before schedule {start_at}",
        rec.started_s
    );
}

#[test]
fn concurrent_experiments_on_distinct_pipelines() {
    // multi-endpoint experiments: two variants measured simultaneously on
    // the shared cluster, then OpenCost-style allocation splits the node
    // cost between their namespaces
    use plantd::cost::{allocate_node_costs, namespace_cost};
    let harness = std::sync::Arc::new(ExperimentHarness::new(400.0));
    let exp = small_exp();
    let h1 = {
        let (harness, exp) = (harness.clone(), exp.clone());
        std::thread::spawn(move || {
            harness
                .run(&VariantConfig::no_blocking_write(), &exp)
                .unwrap()
        })
    };
    let h2 = {
        let (harness, exp) = (harness.clone(), exp.clone());
        std::thread::spawn(move || {
            harness.run(&VariantConfig::blocking_write(), &exp).unwrap()
        })
    };
    let r1 = h1.join().unwrap();
    let r2 = h2.join().unwrap();
    assert_eq!(r1.zips_sent, 40);
    assert_eq!(r2.zips_sent, 40);

    // allocation: both namespaces metered usage on the shared node
    let node = harness.cloud.node("wind-tunnel-node").unwrap();
    let containers = harness.cloud.containers();
    let t1 = r1.drained_s.max(r2.drained_s);
    let allocs = allocate_node_costs(
        node.price_per_hr * t1 / 3600.0,
        node.capacity.vcpus,
        node.capacity.mem_gb,
        &containers,
        0.0,
        t1,
    );
    let c1 = namespace_cost(&allocs, "pipeline-no-blocking-write");
    let c2 = namespace_cost(&allocs, "pipeline-blocking-write");
    assert!(c1 > 0.0 && c2 > 0.0, "both namespaces must be charged: {c1} {c2}");
    let total: f64 = allocs.iter().map(|a| a.cost).sum();
    assert!((total - node.price_per_hr * t1 / 3600.0).abs() / total < 1e-9);
}

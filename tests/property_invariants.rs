//! Property-based tests over coordinator invariants (routing, batching,
//! queueing, conservation, cost allocation), using the in-tree
//! `util::proptest` harness with deterministic, replayable seeds.

use plantd::bus::Topic;
use plantd::cloud::{Cloud, Resources};
use plantd::cost::{allocate_node_costs, namespace_cost};
use plantd::loadgen::LoadPattern;
use plantd::runtime::{native::NativeBackend, ScenarioParams, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::util::json::Json;
use plantd::util::proptest::check;
use plantd::util::rng::Rng;
use plantd::util::stats;

fn random_pattern(rng: &mut Rng) -> LoadPattern {
    let n_segs = rng.int_range(1, 5) as usize;
    let mut p = LoadPattern::default();
    for _ in 0..n_segs {
        p = p.then(
            rng.uniform(0.5, 60.0),
            rng.uniform(0.0, 30.0),
            rng.uniform(0.0, 30.0),
        );
    }
    p
}

#[test]
fn prop_load_schedule_is_monotone_and_area_consistent() {
    check("load-schedule", 60, |rng| {
        let p = random_pattern(rng);
        let times = p.send_times();
        // count matches the integral of the rate curve
        assert_eq!(times.len() as u64, p.total_records());
        // monotone, within the pattern duration
        let total = p.total_duration_s();
        for w in times.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "schedule not monotone");
        }
        if let Some(&last) = times.last() {
            assert!(last <= total + 1e-6, "send after pattern end");
        }
        // cumulative area at each send time equals the 1-based send index
        for (k, &t) in times.iter().enumerate().step_by(7) {
            let mut area = 0.0;
            let mut t0 = 0.0;
            for s in &p.segments {
                let span = (t - t0).clamp(0.0, s.duration_s);
                let r0 = s.start_rps;
                let slope = (s.end_rps - s.start_rps) / s.duration_s;
                area += r0 * span + slope * span * span / 2.0;
                t0 += s.duration_s;
            }
            assert!(
                (area - (k + 1) as f64).abs() < 1e-4,
                "area {area} != {} at t={t}",
                k + 1
            );
        }
    });
}

#[test]
fn prop_arrival_count_matches_total_records_and_is_monotone() {
    // the ISSUE-2 satellite property: for steady, ramp, and composed
    // patterns, send_times().len() == total_records(), inter-arrival
    // times are non-negative (monotone schedule), and the lazy
    // ArrivalStream agrees with the eager schedule bit-for-bit
    check("arrival-count-monotone", 80, |rng| {
        let p = match rng.int_range(0, 3) {
            0 => LoadPattern::steady(rng.uniform(0.5, 120.0), rng.uniform(0.05, 25.0)),
            1 => LoadPattern::ramp(
                rng.uniform(0.5, 120.0),
                rng.uniform(0.05, 25.0),
                rng.uniform(0.05, 25.0),
            ),
            2 => LoadPattern::bursty(
                rng.uniform(20.0, 90.0),
                rng.uniform(0.05, 2.0),
                rng.uniform(5.0, 20.0),
                rng.uniform(1.0, 4.0),
                rng.uniform(2.0, 12.0),
            ),
            _ => random_pattern(rng), // composed multi-segment
        };
        let times = p.send_times();
        assert_eq!(
            times.len() as u64,
            p.total_records(),
            "count != area for {:?}",
            p.segments
        );
        assert!(times.iter().all(|&t| t >= 0.0), "negative send time");
        for w in times.windows(2) {
            assert!(w[1] - w[0] >= 0.0, "negative inter-arrival time");
        }
        if let Some(&last) = times.last() {
            assert!(last <= p.total_duration_s() + 1e-6, "send after pattern end");
        }
        // the lazy stream is the same schedule, bit for bit
        for (eager, lazy) in times.iter().zip(p.arrivals()) {
            assert_eq!(eager.to_bits(), lazy.to_bits(), "stream != schedule");
        }
        assert_eq!(p.arrivals().count(), times.len());
    });
}

#[test]
fn prop_topic_conserves_messages() {
    check("topic-conservation", 25, |rng| {
        let cap = rng.int_range(1, 64) as usize;
        let n_producers = rng.int_range(1, 4) as usize;
        let n_consumers = rng.int_range(1, 4) as usize;
        let per_producer = rng.int_range(1, 300) as u64;
        let topic: Topic<u64> = Topic::new("prop", cap);
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let t = topic.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    t.send(p as u64 * 1_000_000 + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..n_consumers {
            let t = topic.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = t.recv() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        topic.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(
            all.len() as u64,
            n_producers as u64 * per_producer,
            "lost or duplicated messages"
        );
        let (enq, deq) = topic.counters();
        assert_eq!(enq, deq);
        assert!(topic.is_drained());
    });
}

#[test]
fn prop_lindley_invariants_under_random_traffic() {
    let backend = NativeBackend;
    check("lindley-invariants", 20, |rng| {
        let mut model = TrafficModel::nominal();
        model.base_rps = rng.uniform(0.1, 12.0);
        model.growth_factor = rng.uniform(0.5, 2.5);
        for f in model.month_f.iter_mut() {
            *f = rng.uniform(0.5, 1.5);
        }
        let caps = [
            rng.uniform(0.2, 10.0),
            rng.uniform(0.2, 10.0),
            1e9, // infinite-capacity control slot
        ];
        let scenarios: Vec<ScenarioParams> = caps
            .iter()
            .map(|&cap_rps| ScenarioParams {
                cap_rps,
                base_latency_s: rng.uniform(0.01, 1.0),
            })
            .collect();
        let out = backend.twin_sim(&model, &scenarios).unwrap();
        let total_load: f64 = out.load.iter().sum();
        for s in 0..3 {
            // non-negative queue, capped throughput, conservation
            assert!(out.queue[s].iter().all(|&q| q >= 0.0));
            let cap_hr = caps[s] * 3600.0;
            assert!(out.throughput[s].iter().all(|&t| t <= cap_hr * (1.0 + 1e-9)));
            let processed: f64 = out.throughput[s].iter().sum();
            let backlog = out.queue[s].last().unwrap();
            assert!(
                ((processed + backlog) - total_load).abs() / total_load.max(1.0) < 1e-6,
                "conservation violated for scenario {s}"
            );
            // monotonicity: a slower twin never has a shorter queue
        }
        // control slot never queues
        assert!(out.queue[2].iter().all(|&q| q == 0.0));
        // dominance: lower capacity => pointwise >= queue
        let (lo, hi) = if caps[0] <= caps[1] { (0, 1) } else { (1, 0) };
        for t in 0..out.queue[0].len() {
            assert!(
                out.queue[lo][t] >= out.queue[hi][t] - 1e-6,
                "queue dominance violated at hour {t}"
            );
        }
    });
}

#[test]
fn prop_retention_window_monotone_and_bounded() {
    let backend = NativeBackend;
    check("retention-monotone", 25, |rng| {
        let daily: Vec<f64> = (0..365).map(|_| rng.uniform(0.0, 5.0)).collect();
        let w1 = rng.uniform(1.0, 180.0);
        let w2 = w1 + rng.uniform(1.0, 180.0);
        let s1 = backend.retention(&daily, w1).unwrap();
        let s2 = backend.retention(&daily, w2).unwrap();
        let total: f64 = daily.iter().sum();
        for d in 0..365 {
            // longer window stores at least as much
            assert!(s2[d] >= s1[d] - 1e-9, "window monotonicity at day {d}");
            // never more than everything ingested so far
            assert!(s1[d] <= total + 1e-9);
        }
    });
}

#[test]
fn prop_cost_allocation_conserves_node_cost() {
    check("opencost-conservation", 30, |rng| {
        let cloud = Cloud::new();
        let cap = Resources::new(16.0, 64.0);
        let node_cost = rng.uniform(0.05, 3.0);
        cloud.add_node("n", cap, node_cost);
        let n_containers = rng.int_range(1, 6) as usize;
        let mut containers = Vec::new();
        for i in 0..n_containers {
            let c = cloud.deploy(
                &format!("c{i}"),
                if rng.chance(0.5) { "pipeline" } else { "other" },
                "n",
                Resources::new(rng.uniform(0.1, 2.0), rng.uniform(0.1, 8.0)),
            );
            // random usage within the hour
            let busy = rng.uniform(0.0, 3600.0);
            c.record_usage(0.0, busy, busy * rng.uniform(0.1, 1.0), rng.uniform(0.1, 4.0));
            containers.push(c);
        }
        let allocs = allocate_node_costs(node_cost, 16.0, 64.0, &containers, 0.0, 3600.0);
        let total: f64 = allocs.iter().map(|a| a.cost).sum();
        assert!(
            (total - node_cost).abs() < 1e-9,
            "allocation total {total} != node cost {node_cost}"
        );
        assert!(allocs.iter().all(|a| a.cost >= -1e-12), "negative allocation");
        let p = namespace_cost(&allocs, "pipeline");
        let o = namespace_cost(&allocs, "other");
        assert!((p + o - node_cost).abs() < 1e-9);
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.int_range(0, 3) } else { rng.int_range(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal(0.0, 1e6) * 1000.0).round() / 1000.0),
            3 => {
                let len = rng.int_range(0, 12) as usize;
                Json::Str(rng.alphanumeric(len))
            }
            4 => Json::arr((0..rng.int_range(0, 4)).map(|_| random_json(rng, depth - 1))),
            _ => Json::Obj(
                (0..rng.int_range(0, 4))
                    .map(|_| (rng.alphanumeric(4), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json-roundtrip", 200, |rng| {
        let doc = random_json(rng, 3);
        let compact = doc.to_string_compact();
        let pretty = doc.to_string_pretty();
        assert_eq!(Json::parse(&compact).unwrap(), doc, "compact roundtrip");
        assert_eq!(Json::parse(&pretty).unwrap(), doc, "pretty roundtrip");
    });
}

#[test]
fn prop_weighted_stats_degenerate_to_unweighted() {
    check("weighted-stats", 50, |rng| {
        let n = rng.int_range(1, 200) as usize;
        let values: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 100.0)).collect();
        let w = vec![1.0; n];
        let wm = stats::weighted_mean(&values, &w);
        let m = stats::mean(&values);
        assert!((wm - m).abs() < 1e-9);
        let q = rng.f64();
        let wq = stats::weighted_quantile(&values, &w, q);
        // the weighted quantile of uniform weights is an order statistic
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(sorted.contains(&wq));
        // fraction below its own quantile is >= q
        let frac = stats::weighted_fraction_below(&values, &w, wq);
        assert!(frac >= q - 1e-9);
    });
}

#[test]
fn prop_datagen_formats_roundtrip() {
    use plantd::datagen::{
        decode_subsystem_binary, encode_subsystem_binary, SubsystemRecord, SUBSYSTEMS,
    };
    check("binary-roundtrip", 60, |rng| {
        let subsys = rng.int_range(0, SUBSYSTEMS.len() as i64 - 1) as usize;
        let n_fields = SUBSYSTEMS[subsys].1.len();
        let n = rng.int_range(0, 40) as usize;
        let records: Vec<SubsystemRecord> = (0..n)
            .map(|_| SubsystemRecord {
                timestamp_ms: rng.next_u64() % 4_000_000_000_000,
                vin: {
                    let len = rng.int_range(1, 17) as usize;
                    rng.alphanumeric(len)
                },
                values: (0..n_fields)
                    .map(|_| rng.normal(0.0, 1e4) as f32)
                    .collect(),
            })
            .collect();
        let bin = encode_subsystem_binary(subsys, &records);
        let (got_subsys, got) = decode_subsystem_binary(&bin).unwrap();
        assert_eq!(got_subsys, subsys);
        assert_eq!(got, records);
        // single-bit corruption anywhere must be detected
        if !bin.is_empty() {
            let mut corrupt = bin.clone();
            let pos = rng.int_range(0, bin.len() as i64 - 1) as usize;
            corrupt[pos] ^= 1 << rng.int_range(0, 7);
            assert!(
                decode_subsystem_binary(&corrupt).is_err()
                    || corrupt == bin, // bit flip may be identity on some encodings
                "corruption at byte {pos} not detected"
            );
        }
    });
}

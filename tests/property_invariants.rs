//! Property-based tests over coordinator invariants (routing, batching,
//! queueing, conservation, cost allocation), using the in-tree
//! `util::proptest` harness with deterministic, replayable seeds.

use plantd::bus::Topic;
use plantd::cloud::{Cloud, Resources};
use plantd::cost::{allocate_node_costs, namespace_cost};
use plantd::loadgen::LoadPattern;
use plantd::resources::spec::TypedSpec;
use plantd::resources::Kind;
use plantd::runtime::{native::NativeBackend, ScenarioParams, SimBackend};
use plantd::traffic::TrafficModel;
use plantd::util::json::Json;
use plantd::util::proptest::check;
use plantd::util::rng::Rng;
use plantd::util::stats;

fn random_pattern(rng: &mut Rng) -> LoadPattern {
    let n_segs = rng.int_range(1, 5) as usize;
    let mut p = LoadPattern::default();
    for _ in 0..n_segs {
        p = p.then(
            rng.uniform(0.5, 60.0),
            rng.uniform(0.0, 30.0),
            rng.uniform(0.0, 30.0),
        );
    }
    p
}

#[test]
fn prop_load_schedule_is_monotone_and_area_consistent() {
    check("load-schedule", 60, |rng| {
        let p = random_pattern(rng);
        let times = p.send_times();
        // count matches the integral of the rate curve
        assert_eq!(times.len() as u64, p.total_records());
        // monotone, within the pattern duration
        let total = p.total_duration_s();
        for w in times.windows(2) {
            assert!(w[1] >= w[0] - 1e-9, "schedule not monotone");
        }
        if let Some(&last) = times.last() {
            assert!(last <= total + 1e-6, "send after pattern end");
        }
        // cumulative area at each send time equals the 1-based send index
        for (k, &t) in times.iter().enumerate().step_by(7) {
            let mut area = 0.0;
            let mut t0 = 0.0;
            for s in &p.segments {
                let span = (t - t0).clamp(0.0, s.duration_s);
                let r0 = s.start_rps;
                let slope = (s.end_rps - s.start_rps) / s.duration_s;
                area += r0 * span + slope * span * span / 2.0;
                t0 += s.duration_s;
            }
            assert!(
                (area - (k + 1) as f64).abs() < 1e-4,
                "area {area} != {} at t={t}",
                k + 1
            );
        }
    });
}

#[test]
fn prop_arrival_count_matches_total_records_and_is_monotone() {
    // the ISSUE-2 satellite property: for steady, ramp, and composed
    // patterns, send_times().len() == total_records(), inter-arrival
    // times are non-negative (monotone schedule), and the lazy
    // ArrivalStream agrees with the eager schedule bit-for-bit
    check("arrival-count-monotone", 80, |rng| {
        let p = match rng.int_range(0, 3) {
            0 => LoadPattern::steady(rng.uniform(0.5, 120.0), rng.uniform(0.05, 25.0)),
            1 => LoadPattern::ramp(
                rng.uniform(0.5, 120.0),
                rng.uniform(0.05, 25.0),
                rng.uniform(0.05, 25.0),
            ),
            2 => LoadPattern::bursty(
                rng.uniform(20.0, 90.0),
                rng.uniform(0.05, 2.0),
                rng.uniform(5.0, 20.0),
                rng.uniform(1.0, 4.0),
                rng.uniform(2.0, 12.0),
            ),
            _ => random_pattern(rng), // composed multi-segment
        };
        let times = p.send_times();
        assert_eq!(
            times.len() as u64,
            p.total_records(),
            "count != area for {:?}",
            p.segments
        );
        assert!(times.iter().all(|&t| t >= 0.0), "negative send time");
        for w in times.windows(2) {
            assert!(w[1] - w[0] >= 0.0, "negative inter-arrival time");
        }
        if let Some(&last) = times.last() {
            assert!(last <= p.total_duration_s() + 1e-6, "send after pattern end");
        }
        // the lazy stream is the same schedule, bit for bit
        for (eager, lazy) in times.iter().zip(p.arrivals()) {
            assert_eq!(eager.to_bits(), lazy.to_bits(), "stream != schedule");
        }
        assert_eq!(p.arrivals().count(), times.len());
    });
}

#[test]
fn prop_topic_conserves_messages() {
    check("topic-conservation", 25, |rng| {
        let cap = rng.int_range(1, 64) as usize;
        let n_producers = rng.int_range(1, 4) as usize;
        let n_consumers = rng.int_range(1, 4) as usize;
        let per_producer = rng.int_range(1, 300) as u64;
        let topic: Topic<u64> = Topic::new("prop", cap);
        let mut producers = Vec::new();
        for p in 0..n_producers {
            let t = topic.clone();
            producers.push(std::thread::spawn(move || {
                for i in 0..per_producer {
                    t.send(p as u64 * 1_000_000 + i).unwrap();
                }
            }));
        }
        let mut consumers = Vec::new();
        for _ in 0..n_consumers {
            let t = topic.clone();
            consumers.push(std::thread::spawn(move || {
                let mut got = Vec::new();
                while let Some(v) = t.recv() {
                    got.push(v);
                }
                got
            }));
        }
        for p in producers {
            p.join().unwrap();
        }
        topic.close();
        let mut all: Vec<u64> = consumers
            .into_iter()
            .flat_map(|c| c.join().unwrap())
            .collect();
        all.sort_unstable();
        all.dedup();
        assert_eq!(
            all.len() as u64,
            n_producers as u64 * per_producer,
            "lost or duplicated messages"
        );
        let (enq, deq) = topic.counters();
        assert_eq!(enq, deq);
        assert!(topic.is_drained());
    });
}

#[test]
fn prop_lindley_invariants_under_random_traffic() {
    let backend = NativeBackend;
    check("lindley-invariants", 20, |rng| {
        let mut model = TrafficModel::nominal();
        model.base_rps = rng.uniform(0.1, 12.0);
        model.growth_factor = rng.uniform(0.5, 2.5);
        for f in model.month_f.iter_mut() {
            *f = rng.uniform(0.5, 1.5);
        }
        let caps = [
            rng.uniform(0.2, 10.0),
            rng.uniform(0.2, 10.0),
            1e9, // infinite-capacity control slot
        ];
        let scenarios: Vec<ScenarioParams> = caps
            .iter()
            .map(|&cap_rps| ScenarioParams {
                cap_rps,
                base_latency_s: rng.uniform(0.01, 1.0),
            })
            .collect();
        let out = backend.twin_sim(&model, &scenarios).unwrap();
        let total_load: f64 = out.load.iter().sum();
        for s in 0..3 {
            // non-negative queue, capped throughput, conservation
            assert!(out.queue[s].iter().all(|&q| q >= 0.0));
            let cap_hr = caps[s] * 3600.0;
            assert!(out.throughput[s].iter().all(|&t| t <= cap_hr * (1.0 + 1e-9)));
            let processed: f64 = out.throughput[s].iter().sum();
            let backlog = out.queue[s].last().unwrap();
            assert!(
                ((processed + backlog) - total_load).abs() / total_load.max(1.0) < 1e-6,
                "conservation violated for scenario {s}"
            );
            // monotonicity: a slower twin never has a shorter queue
        }
        // control slot never queues
        assert!(out.queue[2].iter().all(|&q| q == 0.0));
        // dominance: lower capacity => pointwise >= queue
        let (lo, hi) = if caps[0] <= caps[1] { (0, 1) } else { (1, 0) };
        for t in 0..out.queue[0].len() {
            assert!(
                out.queue[lo][t] >= out.queue[hi][t] - 1e-6,
                "queue dominance violated at hour {t}"
            );
        }
    });
}

#[test]
fn prop_retention_window_monotone_and_bounded() {
    let backend = NativeBackend;
    check("retention-monotone", 25, |rng| {
        let daily: Vec<f64> = (0..365).map(|_| rng.uniform(0.0, 5.0)).collect();
        let w1 = rng.uniform(1.0, 180.0);
        let w2 = w1 + rng.uniform(1.0, 180.0);
        let s1 = backend.retention(&daily, w1).unwrap();
        let s2 = backend.retention(&daily, w2).unwrap();
        let total: f64 = daily.iter().sum();
        for d in 0..365 {
            // longer window stores at least as much
            assert!(s2[d] >= s1[d] - 1e-9, "window monotonicity at day {d}");
            // never more than everything ingested so far
            assert!(s1[d] <= total + 1e-9);
        }
    });
}

#[test]
fn prop_cost_allocation_conserves_node_cost() {
    check("opencost-conservation", 30, |rng| {
        let cloud = Cloud::new();
        let cap = Resources::new(16.0, 64.0);
        let node_cost = rng.uniform(0.05, 3.0);
        cloud.add_node("n", cap, node_cost);
        let n_containers = rng.int_range(1, 6) as usize;
        let mut containers = Vec::new();
        for i in 0..n_containers {
            let c = cloud.deploy(
                &format!("c{i}"),
                if rng.chance(0.5) { "pipeline" } else { "other" },
                "n",
                Resources::new(rng.uniform(0.1, 2.0), rng.uniform(0.1, 8.0)),
            );
            // random usage within the hour
            let busy = rng.uniform(0.0, 3600.0);
            c.record_usage(0.0, busy, busy * rng.uniform(0.1, 1.0), rng.uniform(0.1, 4.0));
            containers.push(c);
        }
        let allocs = allocate_node_costs(node_cost, 16.0, 64.0, &containers, 0.0, 3600.0);
        let total: f64 = allocs.iter().map(|a| a.cost).sum();
        assert!(
            (total - node_cost).abs() < 1e-9,
            "allocation total {total} != node cost {node_cost}"
        );
        assert!(allocs.iter().all(|a| a.cost >= -1e-12), "negative allocation");
        let p = namespace_cost(&allocs, "pipeline");
        let o = namespace_cost(&allocs, "other");
        assert!((p + o - node_cost).abs() < 1e-9);
    });
}

#[test]
fn prop_json_roundtrip_random_documents() {
    fn random_json(rng: &mut Rng, depth: u32) -> Json {
        match if depth == 0 { rng.int_range(0, 3) } else { rng.int_range(0, 5) } {
            0 => Json::Null,
            1 => Json::Bool(rng.chance(0.5)),
            2 => Json::Num((rng.normal(0.0, 1e6) * 1000.0).round() / 1000.0),
            3 => {
                let len = rng.int_range(0, 12) as usize;
                Json::Str(rng.alphanumeric(len))
            }
            4 => Json::arr((0..rng.int_range(0, 4)).map(|_| random_json(rng, depth - 1))),
            _ => Json::Obj(
                (0..rng.int_range(0, 4))
                    .map(|_| (rng.alphanumeric(4), random_json(rng, depth - 1)))
                    .collect(),
            ),
        }
    }
    check("json-roundtrip", 200, |rng| {
        let doc = random_json(rng, 3);
        let compact = doc.to_string_compact();
        let pretty = doc.to_string_pretty();
        assert_eq!(Json::parse(&compact).unwrap(), doc, "compact roundtrip");
        assert_eq!(Json::parse(&pretty).unwrap(), doc, "pretty roundtrip");
    });
}

/// Parse a raw spec as `kind`, serialize, re-parse, re-serialize: the
/// two serialized forms must be byte-identical pretty JSON (the typed
/// specs are fixed points under `from_json ∘ to_json`).
fn assert_spec_fixed_point(kind: Kind, raw: &Json) {
    let s1 = TypedSpec::parse(kind, raw)
        .unwrap_or_else(|e| panic!("{} spec rejected: {e}\n{raw:?}", kind.as_str()));
    let j1 = s1.to_json();
    let s2 = TypedSpec::parse(kind, &j1)
        .unwrap_or_else(|e| panic!("{} re-parse rejected: {e}", kind.as_str()));
    assert_eq!(
        j1.to_string_pretty(),
        s2.to_json().to_string_pretty(),
        "{} spec round-trip not byte-identical",
        kind.as_str()
    );
}

#[test]
fn prop_resource_specs_roundtrip_byte_identical() {
    check("spec-roundtrip", 60, |rng| {
        // LoadPattern: random multi-segment pattern
        assert_spec_fixed_point(Kind::LoadPattern, &random_pattern(rng).to_json());
        // DataSet: random synthesis parameters
        assert_spec_fixed_point(
            Kind::DataSet,
            &Json::obj(vec![
                ("schema", Json::str(rng.alphanumeric(6))),
                ("payloads", Json::Num(rng.int_range(1, 256) as f64)),
                (
                    "records_per_subsystem",
                    Json::Num(rng.int_range(1, 64) as f64),
                ),
                ("bad_rate", Json::Num((rng.f64() * 1000.0).round() / 1000.0)),
                ("seed", Json::Num(rng.int_range(0, 1 << 50) as f64)),
            ]),
        );
        // Pipeline: every known variant
        let variants = ["blocking-write", "no-blocking-write", "cpu-limited"];
        assert_spec_fixed_point(
            Kind::Pipeline,
            &Json::obj(vec![("variant", Json::str(*rng.choice(&variants)))]),
        );
        // Experiment: random refs, mode, scale — and the campaign form
        let modes = ["real", "sim", "both"];
        assert_spec_fixed_point(
            Kind::Experiment,
            &Json::obj(vec![
                ("dataset", Json::str(rng.alphanumeric(5))),
                ("load_pattern", Json::str(rng.alphanumeric(5))),
                (
                    "pipelines",
                    Json::arr(
                        (0..rng.int_range(1, 3)).map(|_| Json::str(rng.alphanumeric(4))),
                    ),
                ),
                ("mode", Json::str(*rng.choice(&modes))),
                ("scale", Json::Num(rng.int_range(1, 5000) as f64)),
            ]),
        );
        assert_spec_fixed_point(
            Kind::Experiment,
            &Json::obj(vec![(
                "campaign",
                Json::obj(vec![
                    ("grid", Json::str(if rng.chance(0.5) { "paper" } else { "extended" })),
                    ("seed", Json::Num(rng.int_range(0, 1 << 40) as f64)),
                    ("threads", Json::Num(rng.int_range(1, 16) as f64)),
                ]),
            )]),
        );
        // TrafficModel: preset and inline forms
        assert_spec_fixed_point(
            Kind::TrafficModel,
            &Json::obj(vec![(
                "preset",
                Json::str(if rng.chance(0.5) { "nominal" } else { "high" }),
            )]),
        );
        assert_spec_fixed_point(
            Kind::TrafficModel,
            &Json::obj(vec![
                ("name", Json::str(rng.alphanumeric(5))),
                ("base_rps", Json::Num((rng.uniform(0.1, 20.0) * 100.0).round() / 100.0)),
                (
                    "growth_factor",
                    Json::Num((rng.uniform(0.5, 2.0) * 100.0).round() / 100.0),
                ),
            ]),
        );
        // DigitalTwin: all three source forms
        assert_spec_fixed_point(
            Kind::DigitalTwin,
            &Json::obj(vec![("experiment", Json::str(rng.alphanumeric(5)))]),
        );
        assert_spec_fixed_point(Kind::DigitalTwin, &Json::obj(vec![("paper", Json::Bool(true))]));
        assert_spec_fixed_point(
            Kind::DigitalTwin,
            &Json::obj(vec![(
                "params",
                Json::obj(vec![
                    ("name", Json::str(rng.alphanumeric(5))),
                    (
                        "kind",
                        Json::str(if rng.chance(0.5) { "simple" } else { "quickscaling" }),
                    ),
                    ("max_rps", Json::Num((rng.uniform(0.1, 10.0) * 100.0).round() / 100.0)),
                    (
                        "cost_per_hr",
                        Json::Num((rng.uniform(0.001, 0.1) * 1e4).round() / 1e4),
                    ),
                    (
                        "avg_latency_s",
                        Json::Num((rng.uniform(0.01, 1.0) * 100.0).round() / 100.0),
                    ),
                ]),
            )]),
        );
        // Simulation: random twin/forecast lists + SLO
        assert_spec_fixed_point(
            Kind::Simulation,
            &Json::obj(vec![
                (
                    "twins",
                    Json::arr((0..rng.int_range(1, 3)).map(|_| Json::str(rng.alphanumeric(4)))),
                ),
                (
                    "traffic_models",
                    Json::arr((0..rng.int_range(1, 3)).map(|_| Json::str(rng.alphanumeric(4)))),
                ),
                ("slo_hours", Json::Num(rng.int_range(1, 24) as f64)),
                ("slo_frac", Json::Num((rng.f64() * 100.0).round() / 100.0)),
            ]),
        );
        // Schema: a random field list (types drawn from the full set)
        let kinds = ["vin", "uuid", "word", "name", "email", "latlon", "ipv4"];
        assert_spec_fixed_point(
            Kind::Schema,
            &Json::obj(vec![(
                "fields",
                Json::arr((0..rng.int_range(0, 4)).map(|i| {
                    Json::obj(vec![
                        ("name", Json::str(format!("f{i}"))),
                        ("kind", Json::str(*rng.choice(&kinds))),
                    ])
                })),
            )]),
        );
        // Validation: every suite selector, random thread counts
        let suites = ["queueing", "snapshots", "all"];
        assert_spec_fixed_point(
            Kind::Validation,
            &Json::obj(vec![
                ("suite", Json::str(*rng.choice(&suites))),
                ("threads", Json::Num(rng.int_range(1, 16) as f64)),
            ]),
        );
        assert_spec_fixed_point(
            Kind::Validation,
            &Json::obj(vec![
                ("suite", Json::str("snapshots")),
                ("threads", Json::Num(2.0)),
                ("golden_dir", Json::str(rng.alphanumeric(8))),
            ]),
        );
    });
}

#[test]
fn json_string_escaping_edge_cases() {
    for s in [
        "quote \" backslash \\ slash /",
        "tab\there nl\nthere cr\rback",
        "low controls \u{1}\u{8}\u{c}\u{1f}",
        "del \u{7f} nbsp \u{a0}",
        "unicode héllo 世界 😀 \u{10FFFF}",
        "",
    ] {
        let j = Json::Str(s.to_string());
        let compact = j.to_string_compact();
        assert_eq!(Json::parse(&compact).unwrap(), j, "compact: {compact}");
        let pretty = j.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), j, "pretty: {pretty}");
    }
    // \u escape forms (incl. a surrogate pair) decode on the way in
    assert_eq!(
        Json::parse(r#""\u0041\u00e9\ud83d\ude00""#).unwrap().as_str(),
        Some("Aé😀")
    );
    // lone surrogates are rejected, not smuggled through
    assert!(Json::parse(r#""\ud800""#).is_err());
}

#[test]
fn json_large_integer_edge_cases() {
    // 2^53 is exactly representable and round-trips as an integer
    let j = Json::parse("9007199254740992").unwrap();
    assert_eq!(j.as_u64(), Some(9_007_199_254_740_992));
    assert_eq!(j.to_string_compact(), "9007199254740992");
    // 2^53 + 1 is NOT representable: documents the f64 rounding
    let j = Json::parse("9007199254740993").unwrap();
    assert_eq!(j.as_u64(), Some(9_007_199_254_740_992));
    // >= 1e15 serializes via the float path but still re-parses equal
    let j = Json::Num(1e15);
    assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    let j = Json::Num(1e21);
    assert_eq!(Json::parse(&j.to_string_compact()).unwrap(), j);
    // u64::MAX parses (rounded to 2^64) and as_u64 saturates
    let j = Json::parse("18446744073709551615").unwrap();
    assert_eq!(j.as_u64(), Some(u64::MAX));
    // negatives and fractions are still rejected by as_u64
    assert_eq!(Json::parse("-5").unwrap().as_u64(), None);
    assert_eq!(Json::parse("2.5").unwrap().as_u64(), None);
}

#[test]
fn prop_weighted_stats_degenerate_to_unweighted() {
    check("weighted-stats", 50, |rng| {
        let n = rng.int_range(1, 200) as usize;
        let values: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 100.0)).collect();
        let w = vec![1.0; n];
        let wm = stats::weighted_mean(&values, &w);
        let m = stats::mean(&values);
        assert!((wm - m).abs() < 1e-9);
        let q = rng.f64();
        let wq = stats::weighted_quantile(&values, &w, q);
        // the weighted quantile of uniform weights is an order statistic
        let mut sorted = values.clone();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert!(sorted.contains(&wq));
        // fraction below its own quantile is >= q
        let frac = stats::weighted_fraction_below(&values, &w, wq);
        assert!(frac >= q - 1e-9);
    });
}

#[test]
fn prop_datagen_formats_roundtrip() {
    use plantd::datagen::{
        decode_subsystem_binary, encode_subsystem_binary, SubsystemRecord, SUBSYSTEMS,
    };
    check("binary-roundtrip", 60, |rng| {
        let subsys = rng.int_range(0, SUBSYSTEMS.len() as i64 - 1) as usize;
        let n_fields = SUBSYSTEMS[subsys].1.len();
        let n = rng.int_range(0, 40) as usize;
        let records: Vec<SubsystemRecord> = (0..n)
            .map(|_| SubsystemRecord {
                timestamp_ms: rng.next_u64() % 4_000_000_000_000,
                vin: {
                    let len = rng.int_range(1, 17) as usize;
                    rng.alphanumeric(len)
                },
                values: (0..n_fields)
                    .map(|_| rng.normal(0.0, 1e4) as f32)
                    .collect(),
            })
            .collect();
        let bin = encode_subsystem_binary(subsys, &records);
        let (got_subsys, got) = decode_subsystem_binary(&bin).unwrap();
        assert_eq!(got_subsys, subsys);
        assert_eq!(got, records);
        // single-bit corruption anywhere must be detected
        if !bin.is_empty() {
            let mut corrupt = bin.clone();
            let pos = rng.int_range(0, bin.len() as i64 - 1) as usize;
            corrupt[pos] ^= 1 << rng.int_range(0, 7);
            assert!(
                decode_subsystem_binary(&corrupt).is_err()
                    || corrupt == bin, // bit flip may be identity on some encodings
                "corruption at byte {pos} not detected"
            );
        }
    });
}

#[test]
fn prop_event_queue_pops_ties_in_stable_time_seq_order() {
    use plantd::sim::EventQueue;
    check("event-queue-stable-ties", 60, |rng| {
        // random interleaved pushes with deliberately colliding times
        // (coarse-grid rounding forces many exact ties); the payload is
        // the push index, so stability is directly observable
        let n = rng.int_range(1, 400) as usize;
        let mut q = EventQueue::new();
        let mut pushed: Vec<f64> = Vec::with_capacity(n);
        for i in 0..n {
            let t = (rng.uniform(0.0, 10.0) * 4.0).round() / 4.0; // 0.25 grid
            q.push(t, i);
            pushed.push(t);
        }
        assert_eq!(q.len(), n);
        let mut popped: Vec<(f64, usize)> = Vec::with_capacity(n);
        while let Some(e) = q.pop() {
            popped.push(e);
        }
        assert_eq!(popped.len(), n, "no event lost or duplicated");
        for w in popped.windows(2) {
            let ((t0, i0), (t1, i1)) = (w[0], w[1]);
            assert!(t1 >= t0, "times must be non-decreasing");
            if t0.to_bits() == t1.to_bits() {
                assert!(
                    i1 > i0,
                    "tie at t={t0}: push #{i1} popped before push #{i0}"
                );
            }
        }
        // every event came back at the time it was pushed with
        for (t, i) in &popped {
            assert_eq!(t.to_bits(), pushed[*i].to_bits());
        }
    });
}

#[test]
fn prop_quantile_matches_sort_based_reference() {
    // an independent "type 7" reference: sort, then interpolate between
    // the two bracketing order statistics
    fn reference(xs: &[f64], q: f64) -> f64 {
        if xs.is_empty() {
            return f64::NAN;
        }
        let mut v = xs.to_vec();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let h = q * (v.len() as f64 - 1.0);
        let lo = h.floor() as usize;
        let frac = h - lo as f64;
        if lo + 1 < v.len() {
            v[lo] + frac * (v[lo + 1] - v[lo])
        } else {
            v[lo]
        }
    }
    check("quantile-vs-reference", 80, |rng| {
        let n = rng.int_range(0, 300) as usize;
        let mut xs: Vec<f64> = (0..n).map(|_| rng.normal(0.0, 50.0)).collect();
        // inject duplicate runs so interpolation hits equal neighbours
        if n >= 4 {
            let dup = xs[0];
            xs[1] = dup;
            xs[2] = dup;
        }
        for _ in 0..8 {
            let q = rng.f64();
            let got = stats::quantile(&xs, q);
            let want = reference(&xs, q);
            if n == 0 {
                assert!(got.is_nan() && want.is_nan());
            } else {
                assert!(
                    (got - want).abs() <= 1e-9 * (1.0 + want.abs()),
                    "q={q}, n={n}: {got} vs {want}"
                );
            }
        }
        // edges: empty, single, duplicates-only
        assert!(stats::quantile(&[], 0.5).is_nan());
        assert_eq!(stats::quantile(&[7.5], 0.0), 7.5);
        assert_eq!(stats::quantile(&[7.5], 1.0), 7.5);
        let dup = [3.0, 3.0, 3.0, 3.0];
        for q in [0.0, 0.25, 0.5, 0.99, 1.0] {
            assert_eq!(stats::quantile(&dup, q), 3.0);
        }
        if n >= 1 {
            assert_eq!(stats::quantile(&xs, 0.0), reference(&xs, 0.0));
            assert_eq!(stats::quantile(&xs, 1.0), reference(&xs, 1.0));
        }
    });
}

//! Integration tests for the declarative resource API: manifest apply →
//! reconcile → run through the controller, state persistence across
//! controller instances (the CLI's `apply` / `run` processes), eventual
//! consistency (heal-on-apply, delete demotion), and byte-for-byte parity
//! between the resource path and the direct domain-type path.

use std::path::PathBuf;

use plantd::campaign::{Campaign, CampaignRunner};
use plantd::resources::controller::Controller;
use plantd::resources::{Kind, Phase, Registry};
use plantd::util::json::Json;

fn example_manifest() -> Json {
    let path = PathBuf::from(env!("CARGO_MANIFEST_DIR"))
        .join("../examples/manifests/windtunnel.json");
    let text = std::fs::read_to_string(&path)
        .unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    Json::parse(&text).unwrap()
}

/// A small, fast manifest with the same shape as the shipped example.
fn small_manifest() -> Json {
    Json::parse(
        r#"{"resources": [
            {"kind": "Schema", "name": "telematics", "spec": {}},
            {"kind": "DataSet", "name": "fleet",
             "spec": {"schema": "telematics", "payloads": 4,
                      "records_per_subsystem": 2, "bad_rate": 0.0, "seed": 9}},
            {"kind": "LoadPattern", "name": "pulse",
             "spec": {"segments": [{"duration_s": 5, "start_rps": 2,
                                    "end_rps": 2}]}},
            {"kind": "Pipeline", "name": "noblock",
             "spec": {"variant": "no-blocking-write"}},
            {"kind": "Experiment", "name": "e1",
             "spec": {"dataset": "fleet", "load_pattern": "pulse",
                      "pipeline": "noblock", "mode": "sim", "scale": 3000}},
            {"kind": "DigitalTwin", "name": "twin",
             "spec": {"experiment": "e1"}},
            {"kind": "TrafficModel", "name": "nominal",
             "spec": {"preset": "nominal"}},
            {"kind": "Simulation", "name": "year",
             "spec": {"twin": "twin", "traffic_model": "nominal"}}
        ]}"#,
    )
    .unwrap()
}

fn temp_dir(tag: &str) -> PathBuf {
    std::env::temp_dir().join(format!("plantd-resource-api-{tag}-{}", std::process::id()))
}

#[test]
fn example_manifest_covers_all_kinds_and_reconciles_ready() {
    let c = Controller::new(Registry::new());
    let applied = c.apply_manifest(&example_manifest()).unwrap();
    assert_eq!(applied.len(), 14);
    c.reconcile();
    for r in c.registry().list_all() {
        assert_eq!(
            r.phase,
            Phase::Ready,
            "{}/{}: {:?}",
            r.kind.as_str(),
            r.name,
            r.conditions
        );
    }
    for kind in Kind::all() {
        assert!(
            !c.registry().list(kind).is_empty(),
            "example manifest must exercise kind {}",
            kind.as_str()
        );
    }
    // the reference DAG orders dependencies first
    let order = c.topo_order();
    let pos = |k: Kind, n: &str| {
        order
            .iter()
            .position(|(ok, on)| *ok == k && on == n)
            .unwrap_or_else(|| panic!("{}/{n} missing from topo order", k.as_str()))
    };
    assert!(pos(Kind::Schema, "telematics") < pos(Kind::DataSet, "fleet-day"));
    assert!(pos(Kind::DataSet, "fleet-day") < pos(Kind::Experiment, "telematics-ramp"));
    assert!(pos(Kind::Experiment, "telematics-ramp") < pos(Kind::DigitalTwin, "fitted"));
    assert!(pos(Kind::DigitalTwin, "fitted") < pos(Kind::Simulation, "what-if"));
    assert!(pos(Kind::TrafficModel, "nominal") < pos(Kind::Simulation, "what-if"));
}

#[test]
fn full_chain_runs_and_statuses_carry_results() {
    let dir = temp_dir("chain");
    let c = Controller::new(Registry::new()).with_out_dir(dir.clone());
    c.apply_manifest(&small_manifest()).unwrap();
    // running the Simulation pulls the whole dependency chain:
    // twin -> experiment -> (dataset, load pattern, pipeline)
    let outcome = c.run(Kind::Simulation, "year").unwrap();
    assert!(outcome.output.contains("TABLE I"));
    assert!(outcome.output.contains("TABLE II"));
    for (kind, name) in [
        (Kind::Experiment, "e1"),
        (Kind::DigitalTwin, "twin"),
        (Kind::Simulation, "year"),
    ] {
        let r = c.registry().get(kind, name).unwrap();
        assert_eq!(r.phase, Phase::Completed, "{}/{name}", kind.as_str());
        assert!(r.status != Json::Null, "{}/{name} status empty", kind.as_str());
    }
    // the experiment's status carries the fitted twin the chain used
    let e = c.registry().get(Kind::Experiment, "e1").unwrap();
    let twins = e.status.get("twins").and_then(Json::as_arr).unwrap();
    assert_eq!(twins.len(), 1);
    assert_eq!(twins[0].get_str("name"), Some("no-blocking-write"));
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn state_persists_across_controller_instances() {
    let dir = temp_dir("state");
    let state = dir.join("registry.json");
    // "process" 1: apply + run the experiment, save
    let c1 = Controller::new(Registry::new()).with_out_dir(dir.clone());
    c1.apply_manifest(&small_manifest()).unwrap();
    c1.run(Kind::Experiment, "e1").unwrap();
    c1.registry().save(&state).unwrap();
    // "process" 2: load the state; the DigitalTwin fits from the
    // persisted experiment status without re-running the experiment
    let reg = Registry::load(&state).unwrap();
    assert_eq!(
        reg.get(Kind::Experiment, "e1").unwrap().phase,
        Phase::Completed
    );
    let c2 = Controller::new(reg).with_out_dir(dir.clone());
    let out = c2.run(Kind::DigitalTwin, "twin").unwrap();
    assert!(out.output.contains("TABLE I"));
    assert!(
        c2.experiment_records("e1").is_none(),
        "twin must come from persisted status, not an experiment re-run"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn apply_heals_failed_dependents_and_delete_demotes() {
    let c = Controller::new(Registry::new());
    c.apply_manifest(
        &Json::parse(r#"{"kind": "DataSet", "name": "d", "spec": {"schema": "s"}}"#)
            .unwrap(),
    )
    .unwrap();
    c.reconcile();
    assert_eq!(c.registry().get(Kind::DataSet, "d").unwrap().phase, Phase::Failed);
    // applying the missing dependency heals the dependent
    c.apply_manifest(&Json::parse(r#"{"kind": "Schema", "name": "s", "spec": {}}"#).unwrap())
        .unwrap();
    c.reconcile();
    assert_eq!(c.registry().get(Kind::DataSet, "d").unwrap().phase, Phase::Ready);
    // deleting it demotes the Ready dependent with a dangling condition
    assert!(c.registry().delete(Kind::Schema, "s"));
    let d = c.registry().get(Kind::DataSet, "d").unwrap();
    assert_eq!(d.phase, Phase::Pending);
    assert!(d.conditions.last().unwrap().contains("dangling reference"));
}

#[test]
fn campaign_resource_matches_direct_runner_byte_for_byte() {
    let c = Controller::new(Registry::new());
    c.apply_manifest(
        &Json::parse(
            r#"{"kind": "Experiment", "name": "sweep",
                "spec": {"campaign": {"grid": "paper", "seed": 7, "threads": 3}}}"#,
        )
        .unwrap(),
    )
    .unwrap();
    let out = c.run(Kind::Experiment, "sweep").unwrap().output;
    let direct = CampaignRunner::new(3).run(&Campaign::paper_automotive(7));
    assert_eq!(
        out,
        format!("{}\n", direct.render()),
        "resource path must reproduce the direct campaign report byte-for-byte"
    );
}

#[test]
fn manifest_errors_are_reported_at_apply_time() {
    let c = Controller::new(Registry::new());
    let bad_kind = Json::parse(r#"{"kind": "Widget", "name": "w", "spec": {}}"#).unwrap();
    assert!(c.apply_manifest(&bad_kind).unwrap_err().contains("Widget"));
    let no_name = Json::parse(r#"{"kind": "Schema", "spec": {}}"#).unwrap();
    assert!(c.apply_manifest(&no_name).unwrap_err().contains("name"));
    let not_a_manifest = Json::parse(r#"{"hello": 1}"#).unwrap();
    assert!(c.apply_manifest(&not_a_manifest).is_err());
}

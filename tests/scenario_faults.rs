//! Oracle-backed accuracy tests for the scenario engine (fault
//! injection, PR 9).
//!
//! The contract under test has three parts:
//!
//! 1. **Conservation** — an M/M/1 station with a mid-run outage window
//!    must conserve arrivals exactly: every offered job is either served
//!    or dropped by the time the tandem drains (in-system is zero at
//!    quiescence by construction), with or without load shedding.
//! 2. **Piecewise analytics** — the faulted trajectory must match the
//!    piecewise-analytic expectation within tolerance: pre-outage
//!    throughput ≈ λ (the queue is stable at ρ = λ/μ < 1), no service
//!    completes inside the outage window beyond the one batch in flight
//!    when it opened, `outage_busy_s` accounts the window exactly, total
//!    busy time ≈ served/μ, and the post-outage backlog peak ≈ λ·window.
//! 3. **Determinism and the empty-scenario identity** — a faulted run is
//!    a pure function of `(arrivals, services, plan)`; an *empty*
//!    `Scenario` attached to the paper campaign is byte-identical to no
//!    scenario at any thread count, and a non-empty one replays
//!    byte-identically across thread counts.
//!
//! `tests/sim_equivalence.rs` pins the same identity at the kernel
//! level (empty `FaultPlan` vs `Tandem::run`, bit for bit); these tests
//! work the scenario layer end-to-end.

use plantd::campaign::{Campaign, CampaignRunner};
use plantd::scenario::{ClampPolicy, LoadOverlay, RetrySpec, Scenario};
use plantd::sim::{FaultPlan, QueuePolicy, Served, StationConfig, Tandem};
use plantd::util::rng::Rng;

/// Arrival rate, jobs/s (λ).
const LAMBDA: f64 = 2.0;
/// Service rate, jobs/s (μ); ρ = 0.5 keeps the queue stable.
const MU: f64 = 4.0;
/// Arrival horizon, virtual seconds.
const HORIZON_S: f64 = 400.0;
/// Outage window: the single server goes down for 60 s mid-run.
const OUTAGE_START_S: f64 = 120.0;
const OUTAGE_END_S: f64 = 180.0;

/// Poisson arrivals over the horizon, pre-sampled so the faulted and
/// plain runs consume identical inputs.
fn mm1_arrivals(seed: u64) -> Vec<(f64, u64)> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    let mut arrivals = Vec::new();
    let mut i = 0u64;
    loop {
        t += rng.exponential(LAMBDA);
        if t >= HORIZON_S {
            break;
        }
        arrivals.push((t, i));
        i += 1;
    }
    assert!(arrivals.len() > 500, "horizon too short for LLN tolerances");
    arrivals
}

/// Pre-sampled exponential service times, indexed by job id — the same
/// pre-sampling idiom the campaign cell model uses, so the service draw
/// stream is independent of the order faults impose.
fn mm1_services(seed: u64, n: usize) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0x5EED);
    (0..n).map(|_| rng.exponential(MU)).collect()
}

fn servicer(services: &[f64]) -> impl FnMut(usize, f64, &mut Vec<u64>) -> Served<u64> + '_ {
    move |_, _, jobs| Served {
        service_s: services[jobs[0] as usize],
        next: jobs.clone(),
    }
}

#[test]
fn outage_conserves_arrivals_and_matches_piecewise_analytics() {
    let arrivals = mm1_arrivals(0xA11);
    let services = mm1_services(0xA11, arrivals.len());
    let n = arrivals.len() as u64;

    let tandem = Tandem::new(vec![StationConfig::single("svc")]);
    let mut plan =
        FaultPlan::new(0xFA).with_outage(0, OUTAGE_START_S, OUTAGE_END_S, 1);
    let out = tandem.run_faulted(arrivals.clone(), servicer(&services), &mut plan);
    let stats = &out.stations[0];

    // conservation: the tandem drains to quiescence, so in-system is 0
    // and every arrival was served (the queue is unbounded — no drops)
    assert_eq!(stats.offered, n);
    assert_eq!(stats.offered, stats.served + stats.dropped, "conservation");
    assert_eq!(stats.dropped, 0, "unbounded queue must not shed");
    assert_eq!(out.completions.len() as u64, stats.served);

    // outage accounting is exact: the counter accrues one server for
    // precisely the window (deficit parking starts the clock at the
    // window edge even if a batch is still in flight)
    let window = OUTAGE_END_S - OUTAGE_START_S;
    assert!(
        (stats.outage_busy_s - window).abs() < 1e-6,
        "outage_busy_s = {}, want {window}",
        stats.outage_busy_s
    );

    // piecewise analytics, pre-outage phase: the M/M/1 is stable at
    // ρ = 0.5, so throughput ≈ λ — completions before the window within
    // 15% of λ·t (LLN over ~240 jobs)
    let before = out
        .completions
        .iter()
        .filter(|(t, _)| *t < OUTAGE_START_S)
        .count() as f64;
    let expect_before = LAMBDA * OUTAGE_START_S;
    assert!(
        (before - expect_before).abs() / expect_before < 0.15,
        "pre-outage completions {before}, analytic {expect_before}"
    );

    // outage phase: nothing completes while the server is parked except
    // the single batch in flight when the window opened
    let during = out
        .completions
        .iter()
        .filter(|(t, _)| *t > OUTAGE_START_S && *t < OUTAGE_END_S)
        .count();
    assert!(during <= 1, "{during} completions inside the outage window");

    // total busy time is the served work: Σ service ≈ served·E[S]
    let expect_busy = stats.served as f64 / MU;
    assert!(
        (stats.busy_s - expect_busy).abs() / expect_busy < 0.10,
        "busy_s = {}, analytic {expect_busy}",
        stats.busy_s
    );

    // backlog peak ≈ λ·window jobs queued while the server was down
    // (Poisson(120): ±3σ ≈ ±33)
    let expect_backlog = LAMBDA * window;
    assert!(
        stats.max_queue as f64 > expect_backlog - 35.0,
        "max_queue = {} never reached the analytic backlog ≈ {expect_backlog}",
        stats.max_queue
    );

    // the faulted run visibly differs from the un-faulted one: same
    // arrivals drain strictly later
    let plain = Tandem::new(vec![StationConfig::single("svc")])
        .run(arrivals, servicer(&services));
    assert!(out.drained_s() > plain.drained_s());
    assert_eq!(plain.stations[0].outage_busy_s, 0.0);
}

#[test]
fn outage_with_load_shedding_conserves_via_drops() {
    let arrivals = mm1_arrivals(0xB22);
    let services = mm1_services(0xB22, arrivals.len());
    let n = arrivals.len() as u64;

    // a bounded queue: the 60 s outage accumulates ~120 arrivals against
    // capacity 25, so shedding is certain — conservation must now route
    // through the dropped counter
    let tandem = Tandem::new(vec![StationConfig::single("svc")
        .with_policy(QueuePolicy::DropNewest { capacity: 25 })]);
    let mut plan =
        FaultPlan::new(0xFB).with_outage(0, OUTAGE_START_S, OUTAGE_END_S, 1);
    let out = tandem.run_faulted(arrivals, servicer(&services), &mut plan);
    let stats = &out.stations[0];

    assert_eq!(stats.offered, n);
    assert_eq!(stats.offered, stats.served + stats.dropped, "conservation");
    assert!(stats.dropped > 0, "the clamped outage must shed load");
    assert_eq!(out.completions.len() as u64, stats.served);
    assert!((stats.outage_busy_s - (OUTAGE_END_S - OUTAGE_START_S)).abs() < 1e-6);
}

#[test]
fn faulted_runs_replay_bit_identically() {
    let arrivals = mm1_arrivals(0xC33);
    let services = mm1_services(0xC33, arrivals.len());
    let run = || {
        let mut plan = FaultPlan::new(0xD4)
            .with_outage(0, OUTAGE_START_S, OUTAGE_END_S, 1)
            .with_slowdown(0, 250.0, 300.0, 3.0);
        Tandem::new(vec![StationConfig::single("svc")]).run_faulted(
            arrivals.clone(),
            servicer(&services),
            &mut plan,
        )
    };
    let a = run();
    let b = run();
    assert_eq!(a.events, b.events);
    assert_eq!(a.completions.len(), b.completions.len());
    for ((ta, ja), (tb, jb)) in a.completions.iter().zip(&b.completions) {
        assert_eq!(ta.to_bits(), tb.to_bits());
        assert_eq!(ja, jb);
    }
    assert_eq!(a.stations[0].busy_s.to_bits(), b.stations[0].busy_s.to_bits());
    assert_eq!(
        a.stations[0].outage_busy_s.to_bits(),
        b.stations[0].outage_busy_s.to_bits()
    );
}

// ---- campaign level: the Scenario resource end-to-end ----------------------

/// The paper scenario exercised across the campaign layer: every
/// primitive class at once.
fn stress_scenario() -> Scenario {
    Scenario::empty("stress")
        .with_outage("v2x", 5.0, 15.0, 1)
        .with_slowdown("etl", 0.0, 10.0, 2.0)
        .with_retry(RetrySpec {
            station: "unzipper".to_string(),
            fail_rate: 0.2,
            max_attempts: 3,
            base_backoff_s: 0.05,
            max_backoff_s: 0.4,
            jitter_frac: 0.25,
        })
        .with_clamp("v2x", 64, ClampPolicy::Drop)
        .with_overlay(LoadOverlay::ColdStartBurst {
            until_s: 5.0,
            factor: 2.0,
        })
}

#[test]
fn empty_scenario_on_the_paper_campaign_is_byte_identical_at_any_thread_count() {
    let plain = CampaignRunner::new(1).run(&Campaign::paper_automotive(0x99));
    let baseline = plain.to_json().to_string_pretty();
    for threads in [1, 3] {
        let with_empty = CampaignRunner::new(threads)
            .run(&Campaign::paper_automotive(0x99).with_scenario(Scenario::empty("noop")));
        assert_eq!(
            baseline,
            with_empty.to_json().to_string_pretty(),
            "empty scenario diverged at {threads} thread(s)"
        );
        assert_eq!(plain.render(), with_empty.render());
    }
}

#[test]
fn faulted_paper_campaign_is_deterministic_and_differs_from_baseline() {
    let scen = stress_scenario();
    scen.validate().expect("stress scenario is well-formed");
    let faulted = Campaign::paper_automotive(0x99).with_scenario(scen);
    let a = CampaignRunner::new(1).run(&faulted);
    let b = CampaignRunner::new(4).run(&faulted);
    assert_eq!(
        a.to_json().to_string_pretty(),
        b.to_json().to_string_pretty(),
        "faulted campaign must replay byte-identically across thread counts"
    );
    let plain = CampaignRunner::new(1).run(&Campaign::paper_automotive(0x99));
    assert_ne!(
        a.to_json().to_string_pretty(),
        plain.to_json().to_string_pretty(),
        "a non-empty scenario must change the numbers"
    );
}

#[test]
fn scenario_json_round_trips_to_a_fixed_point() {
    let scen = stress_scenario();
    let j = scen.to_json();
    let back = Scenario::from_json(&j).expect("serialized scenario parses");
    assert_eq!(back, scen);
    assert_eq!(back.to_json().to_string_pretty(), j.to_string_pretty());
}

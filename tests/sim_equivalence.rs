//! Differential tests pinning the hot-path rewrites (PR 6) to simple
//! reference implementations.
//!
//! The index-based 4-ary heap arena behind `sim::EventQueue`, the
//! batch-draining `Station::start_batch`, and the instrumented
//! `Tandem::run_recorded` path are all performance rewrites whose
//! contract is *behavioral identity*: same pop order, same admissions,
//! same bytes out. Each test here holds the optimized structure against
//! a deliberately naive model under randomized workloads (equal-time
//! entries included — tie-breaking is where heap rewrites go wrong):
//!
//! - `EventQueue` vs a `BinaryHeap` of `(time, seq)` entries — the
//!   exact structure the kernel used before the arena rewrite;
//! - `Station` (FIFO, LIFO, batching, DropNewest, Block) vs a
//!   `Vec`-based model that queues with `insert(0, ..)` / `remove(0)`;
//! - `Tandem::run` vs `Tandem::run_recorded` — instrumentation must
//!   not move a single bit of the outcome.
//!
//! The golden snapshots (`tests/golden_snapshots.rs`) and the queueing
//! conformance suite (`tests/validation_oracle.rs`) prove the same
//! property end-to-end; these tests localize a violation to the
//! structure that caused it.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

use plantd::sim::{
    Discipline, EventQueue, FaultPlan, Offered, PerfRecorder, QueuePolicy, Served, Station,
    StationConfig, Tandem,
};
use plantd::util::proptest::check;
use plantd::util::rng::Rng;

// ---- EventQueue vs BinaryHeap reference ------------------------------------

/// The pre-rewrite event-queue entry: a max-heap entry ordered so the
/// smallest `(time, seq)` pops first, with `total_cmp` tie-breaking —
/// byte-for-byte the ordering the kernel documented before the arena.
struct RefEntry {
    time: f64,
    seq: u64,
    payload: u64,
}

impl PartialEq for RefEntry {
    fn eq(&self, other: &Self) -> bool {
        self.cmp(other) == Ordering::Equal
    }
}
impl Eq for RefEntry {}
impl PartialOrd for RefEntry {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for RefEntry {
    fn cmp(&self, other: &Self) -> Ordering {
        // inverted: BinaryHeap pops the max, we want the min key
        other
            .time
            .total_cmp(&self.time)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

#[test]
fn event_queue_matches_binaryheap_reference_model() {
    check("event-queue-vs-binaryheap", 150, |rng| {
        let mut queue: EventQueue<u64> = if rng.chance(0.5) {
            EventQueue::new()
        } else {
            EventQueue::with_capacity(rng.int_range(0, 32) as usize)
        };
        let mut model: BinaryHeap<RefEntry> = BinaryHeap::new();
        let mut next_seq = 0u64;
        let mut next_payload = 0u64;

        let ops = rng.int_range(1, 250);
        for _ in 0..ops {
            if rng.chance(0.6) {
                // a coarse grid (quarter steps, negatives included)
                // forces frequent equal-time collisions
                let time = rng.int_range(-6, 14) as f64 * 0.25;
                queue.push(time, next_payload);
                model.push(RefEntry {
                    time,
                    seq: next_seq,
                    payload: next_payload,
                });
                next_seq += 1;
                next_payload += 1;
            } else {
                let got = queue.pop();
                let want = model.pop().map(|e| e.payload);
                assert_eq!(got, want, "pop order diverged");
            }
            assert_eq!(queue.len(), model.len(), "len diverged");
            assert_eq!(
                queue.peek_time(),
                model.peek().map(|e| e.time),
                "peek_time diverged"
            );
        }
        // full drain: every remaining entry must come out in the same order
        while let Some(want) = model.pop() {
            assert_eq!(queue.pop(), Some(want.payload), "drain order diverged");
        }
        assert!(queue.is_empty());
    });
}

// ---- Station vs a naive Vec model ------------------------------------------

/// Deliberately naive station model: queue as a `Vec` with `insert(0)` /
/// `remove(0)`, batches taken by repeated `remove(0)` — the semantics
/// `Station` had before the drain-based batching.
struct RefStation {
    batch_max: usize,
    lifo: bool,
    cap: Option<usize>,
    drop_newest: bool,
    idle: usize,
    queue: Vec<u64>,
    blocked: Vec<u64>,
    offered: u64,
    served: u64,
    dropped: u64,
    backpressured: u64,
    batches: u64,
    max_queue: usize,
}

impl RefStation {
    fn new(servers: usize, batch_max: usize, lifo: bool, cap: Option<usize>, drop_newest: bool) -> Self {
        RefStation {
            batch_max,
            lifo,
            cap,
            drop_newest,
            idle: servers,
            queue: Vec::new(),
            blocked: Vec::new(),
            offered: 0,
            served: 0,
            dropped: 0,
            backpressured: 0,
            batches: 0,
            max_queue: 0,
        }
    }

    fn enqueue(&mut self, job: u64) {
        if self.lifo {
            self.queue.insert(0, job);
        } else {
            self.queue.push(job);
        }
        self.max_queue = self.max_queue.max(self.queue.len());
    }

    fn offer(&mut self, job: u64) -> Offered {
        self.offered += 1;
        if let Some(cap) = self.cap {
            if self.queue.len() >= cap {
                return if self.drop_newest {
                    self.dropped += 1;
                    Offered::Dropped
                } else {
                    self.backpressured += 1;
                    self.blocked.push(job);
                    Offered::Blocked
                };
            }
        }
        self.enqueue(job);
        Offered::Queued
    }

    fn start(&mut self) -> Option<Vec<u64>> {
        if self.queue.is_empty() || self.idle == 0 {
            return None;
        }
        self.idle -= 1;
        let n = self.batch_max.min(self.queue.len());
        let jobs: Vec<u64> = (0..n).map(|_| self.queue.remove(0)).collect();
        if let Some(cap) = self.cap {
            while self.queue.len() < cap && !self.blocked.is_empty() {
                let j = self.blocked.remove(0);
                self.enqueue(j);
            }
        }
        self.batches += 1;
        Some(jobs)
    }

    fn complete(&mut self, n_jobs: usize) {
        self.idle += 1;
        self.served += n_jobs as u64;
    }
}

#[test]
fn station_matches_naive_reference_under_random_workloads() {
    check("station-vs-naive-model", 200, |rng| {
        let servers = rng.int_range(1, 3) as usize;
        let batch_max = rng.int_range(1, 4) as usize;
        let lifo = rng.chance(0.5);
        let discipline = if lifo { Discipline::Lifo } else { Discipline::Fifo };
        let (policy, cap, drop_newest) = match rng.int_range(0, 2) {
            0 => (QueuePolicy::Unbounded, None, false),
            1 => {
                let c = rng.int_range(0, 3) as usize;
                (QueuePolicy::DropNewest { capacity: c }, Some(c), true)
            }
            _ => {
                let c = rng.int_range(0, 3) as usize;
                (QueuePolicy::Block { capacity: c }, Some(c), false)
            }
        };
        let mut station: Station<u64> = Station::new(
            StationConfig::single("diff")
                .with_servers(servers)
                .with_batch(batch_max)
                .with_discipline(discipline)
                .with_policy(policy),
        );
        let mut model = RefStation::new(servers, batch_max, lifo, cap, drop_newest);
        // (server id, batch size) pairs in flight, shared by both models
        let mut busy: Vec<(usize, usize)> = Vec::new();
        let mut next_job = 0u64;

        let ops = rng.int_range(20, 160);
        for _ in 0..ops {
            match rng.int_range(0, 2) {
                0 => {
                    let got = station.offer(next_job);
                    let want = model.offer(next_job);
                    assert_eq!(got, want, "admission decision diverged");
                    next_job += 1;
                }
                1 => {
                    let got = station.start_batch();
                    let want = model.start();
                    match (got, want) {
                        (Some((server, jobs)), Some(want_jobs)) => {
                            assert_eq!(jobs, want_jobs, "batch contents diverged");
                            busy.push((server, jobs.len()));
                        }
                        (None, None) => {}
                        (got, want) => panic!(
                            "batch availability diverged: station {:?} vs model {:?}",
                            got.map(|(_, j)| j),
                            want
                        ),
                    }
                }
                _ => {
                    if !busy.is_empty() {
                        let i = rng.int_range(0, busy.len() as i64 - 1) as usize;
                        let (server, n_jobs) = busy.swap_remove(i);
                        station.complete(server, n_jobs);
                        model.complete(n_jobs);
                    }
                }
            }
            assert_eq!(station.queue_len(), model.queue.len(), "queue length diverged");
        }
        // drain to quiescence: start everything startable, complete everything
        loop {
            match (station.start_batch(), model.start()) {
                (Some((server, jobs)), Some(want_jobs)) => {
                    assert_eq!(jobs, want_jobs, "drain batch diverged");
                    busy.push((server, jobs.len()));
                }
                (None, None) => {
                    if let Some((server, n_jobs)) = busy.pop() {
                        station.complete(server, n_jobs);
                        model.complete(n_jobs);
                    } else {
                        break;
                    }
                }
                (got, want) => panic!(
                    "drain availability diverged: station {:?} vs model {:?}",
                    got.map(|(_, j)| j),
                    want
                ),
            }
        }
        assert!(station.is_quiescent(), "station retained work");
        assert!(model.queue.is_empty() && model.blocked.is_empty());

        let s = station.stats();
        assert_eq!(s.offered, model.offered);
        assert_eq!(s.served, model.served);
        assert_eq!(s.dropped, model.dropped);
        assert_eq!(s.backpressured, model.backpressured);
        assert_eq!(s.batches, model.batches);
        assert_eq!(s.max_queue, model.max_queue);
        assert_eq!(s.offered, s.served + s.dropped, "conservation");
    });
}

// ---- Tandem::run vs Tandem::run_recorded -----------------------------------

/// Deterministic pseudo-random service time from (station, job) alone,
/// so both runs see identical draws without sharing an RNG.
fn service_for(station: usize, job: u64) -> f64 {
    let h = (job ^ (station as u64) << 32).wrapping_mul(0x9E37_79B9_7F4A_7C15);
    ((h >> 40) % 1000) as f64 * 1e-3
}

#[test]
fn recorded_tandem_run_is_bit_identical_to_plain_run() {
    check("tandem-recorded-vs-plain", 60, |rng| {
        let n_stations = rng.int_range(1, 3) as usize;
        let configs = || -> Vec<StationConfig> {
            (0..n_stations)
                .map(|i| {
                    let mut c = StationConfig::single(&format!("s{i}"));
                    if i == 0 {
                        c = c.with_batch(3);
                    }
                    if i == 1 {
                        c = c.with_policy(QueuePolicy::DropNewest { capacity: 5 });
                    }
                    c
                })
                .collect()
        };
        // coarse-grid arrival times force equal-timestamp events
        let n = rng.int_range(1, 60) as usize;
        let arrivals: Vec<(f64, u64)> = (0..n as u64)
            .map(|i| ((i % 7) as f64 * 0.5, i))
            .collect();
        let servicer = |station: usize, _start: f64, jobs: &mut Vec<u64>| Served {
            service_s: service_for(station, jobs[0]),
            next: jobs.iter().map(|j| j.wrapping_mul(3)).collect(),
        };

        let plain = Tandem::new(configs()).run(arrivals.clone(), servicer);
        let mut rec = PerfRecorder::with_stride(7);
        let recorded = Tandem::new(configs()).run_recorded(arrivals, servicer, &mut rec);

        assert_eq!(plain.events, recorded.events);
        assert_eq!(plain.completions.len(), recorded.completions.len());
        for ((ta, ja), (tb, jb)) in plain.completions.iter().zip(&recorded.completions) {
            assert_eq!(ta.to_bits(), tb.to_bits(), "completion time moved");
            assert_eq!(ja, jb, "completion order moved");
        }
        for (a, b) in plain.stations.iter().zip(&recorded.stations) {
            assert_eq!(a.offered, b.offered);
            assert_eq!(a.served, b.served);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.batches, b.batches);
            assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits());
            assert_eq!(a.queue_area_s.to_bits(), b.queue_area_s.to_bits());
            assert_eq!(a.max_queue, b.max_queue);
            assert_eq!(a.buffer_allocs, b.buffer_allocs);
        }
        let report = rec.report();
        assert_eq!(report.events, recorded.events, "recorder missed events");
    });
}

// ---- Tandem::run vs Tandem::run_faulted with an empty plan -----------------

#[test]
fn faulted_tandem_run_with_empty_plan_is_bit_identical_to_plain_run() {
    // the FAULTS=true monomorphization with a plan that injects nothing
    // must not move a single bit: same completions, same stats, same
    // event count, and the new fault counters stay zero
    check("tandem-faulted-empty-vs-plain", 60, |rng| {
        let n_stations = rng.int_range(1, 3) as usize;
        let configs = || -> Vec<StationConfig> {
            (0..n_stations)
                .map(|i| {
                    let mut c = StationConfig::single(&format!("s{i}"));
                    if i == 0 {
                        c = c.with_batch(3);
                    }
                    if i == 1 {
                        c = c.with_policy(QueuePolicy::Block { capacity: 4 });
                    }
                    c
                })
                .collect()
        };
        let n = rng.int_range(1, 60) as usize;
        let arrivals: Vec<(f64, u64)> = (0..n as u64)
            .map(|i| ((i % 7) as f64 * 0.5, i))
            .collect();
        let servicer = |station: usize, _start: f64, jobs: &mut Vec<u64>| Served {
            service_s: service_for(station, jobs[0]),
            next: jobs.iter().map(|j| j.wrapping_mul(3)).collect(),
        };

        let plain = Tandem::new(configs()).run(arrivals.clone(), servicer);
        let mut plan = FaultPlan::empty();
        assert!(plan.is_empty());
        let faulted = Tandem::new(configs()).run_faulted(arrivals, servicer, &mut plan);

        assert_eq!(plain.events, faulted.events);
        assert_eq!(plain.completions.len(), faulted.completions.len());
        for ((ta, ja), (tb, jb)) in plain.completions.iter().zip(&faulted.completions) {
            assert_eq!(ta.to_bits(), tb.to_bits(), "completion time moved");
            assert_eq!(ja, jb, "completion order moved");
        }
        for (a, b) in plain.stations.iter().zip(&faulted.stations) {
            assert_eq!(a.offered, b.offered);
            assert_eq!(a.served, b.served);
            assert_eq!(a.dropped, b.dropped);
            assert_eq!(a.backpressured, b.backpressured);
            assert_eq!(a.batches, b.batches);
            assert_eq!(a.busy_s.to_bits(), b.busy_s.to_bits());
            assert_eq!(a.queue_area_s.to_bits(), b.queue_area_s.to_bits());
            assert_eq!(a.max_queue, b.max_queue);
            assert_eq!(a.buffer_allocs, b.buffer_allocs);
            assert_eq!(b.retries, 0, "empty plan must not retry");
            assert_eq!(b.retry_drops, 0);
            assert_eq!(b.outage_busy_s.to_bits(), 0f64.to_bits());
        }
    });
}

// ---- arena stress: slot recycling under sustained load ---------------------

#[test]
fn event_queue_arena_stays_bounded_under_steady_churn() {
    // push/pop churn with bounded in-flight count must not grow the
    // arena: the free list recycles slots (this is the allocation-churn
    // claim the rewrite makes)
    let mut q: EventQueue<u64> = EventQueue::with_capacity(64);
    let mut rng = Rng::new(0xC0FFEE);
    let mut t = 0.0;
    for i in 0..10_000u64 {
        t += rng.exponential(1.0);
        q.push(t, i);
        if q.len() > 32 {
            while q.len() > 16 {
                q.pop();
            }
        }
    }
    assert!(
        q.arena_len() <= 64,
        "arena grew to {} slots with at most 33 in flight",
        q.arena_len()
    );
}

#[test]
fn tandem_batch_buffers_are_recycled_not_reallocated() {
    // A long steady run through a fan-out tandem must allocate at most
    // `servers` batch buffers per station: the Complete arm returns both
    // the batch and the fan-out vector to the station's spare pool, so
    // steady-state service is allocation-free. Randomized shapes so the
    // bound holds for batching and multi-server stations alike.
    check("tandem-buffer-arena-bounded", 40, |rng| {
        let servers: Vec<usize> = (0..3).map(|_| rng.int_range(1, 3) as usize).collect();
        let configs: Vec<StationConfig> = servers
            .iter()
            .enumerate()
            .map(|(i, &sv)| {
                let mut c = StationConfig::single(&format!("s{i}")).with_servers(sv);
                if i == 0 {
                    c = c.with_batch(rng.int_range(1, 3) as usize);
                }
                c
            })
            .collect();
        let n = rng.int_range(200, 800) as u64;
        let mut t = 0.0;
        let arrivals: Vec<(f64, u64)> = (0..n)
            .map(|i| {
                t += rng.exponential(2.0);
                (t, i)
            })
            .collect();
        let out = Tandem::new(configs).run(arrivals, |station, _, jobs| Served {
            service_s: service_for(station, jobs[0]),
            // station 0 fans each zip into two members, like the cell model
            next: if station == 0 {
                jobs.iter().flat_map(|&j| [j, j + 1]).collect()
            } else {
                jobs.clone()
            },
        });
        assert_eq!(out.completions.len(), 2 * n as usize);
        for (stats, &sv) in out.stations.iter().zip(&servers) {
            assert!(
                stats.buffer_allocs <= sv as u64,
                "station '{}' allocated {} batch buffers for {} servers over {} batches",
                stats.name,
                stats.buffer_allocs,
                sv,
                stats.batches
            );
            assert!(stats.batches > stats.buffer_allocs);
        }
    });
}

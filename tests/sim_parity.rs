//! Real-mode vs sim-mode parity: the same pipeline variant, the same
//! experiment definition, executed once on threads against the scaled
//! wall clock and once on the `sim` kernel in virtual time, must agree on
//! throughput within a documented tolerance.
//!
//! ## The tolerance
//!
//! The simulated run is exact: service times are the modeled constants,
//! and virtual pacing has zero lateness. The measured run carries OS
//! scheduling noise, sleep-granularity overshoot, and the stages' *real*
//! CPU work (zip inflation, binary decode) on top of the modeled
//! sleeps — at clock scale ~300–1000 that distortion is below a few
//! percent in release mode but can reach tens of percent on loaded CI
//! machines (the in-tree overload test historically allowed a 0.5–1.4×
//! band vs the analytic capacity for the same reason). We assert
//! **relative throughput error < 0.30** per variant. The band was 0.45
//! while every stage thread serialized its span emission through one
//! shared mutex — the telemetry plane itself perturbed the measured run
//! under load. With spans routed through per-stage lock-free SPSC rings
//! (PR 10) the measurement overhead no longer backs up the stages, so
//! the residual error is the OS-noise floor: the band tightens to 0.30,
//! still wide enough not to flake on a loaded runner, tight enough to
//! catch a broken service model (the three variants' capacities are
//! 1.95 / 6.15 / 0.66 zips/s, i.e. 3–9× apart).
//!
//! The 0.30 band covers *real-vs-sim* only. The simulator itself is
//! held to a far tighter bar: the sim-vs-analytic case at the bottom of
//! this file reuses the `validate` oracle to pin the DES within **2%**
//! of closed-form M/M/1 ground truth — a parity regression in the
//! kernel is caught there at 2%, not here at 30%.

use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;

/// Documented real-vs-sim throughput tolerance (see module docs).
const THROUGHPUT_REL_TOL: f64 = 0.30;

fn saturating_experiment() -> Experiment {
    Experiment::new(
        "parity",
        // saturate every variant so throughput reflects the bottleneck
        // model, not the offered rate: 12 rps ≫ all three capacities
        LoadPattern::steady(5.0, 12.0), // 60 zips
        DataSet::generate(DataSetSpec {
            payloads: 8,
            records_per_subsystem: 4,
            bad_rate: 0.0,
            seed: 0xCAFE,
        }),
    )
}

#[test]
fn real_vs_sim_throughput_within_tolerance_for_paper_variants() {
    // moderate clock scale: fast enough to keep the test short, slow
    // enough that modeled service times dominate the stages' real work
    let harness = ExperimentHarness::new(300.0);
    let exp = saturating_experiment();
    for cfg in VariantConfig::paper_variants() {
        let delta = harness.run_with_sim(&cfg, &exp).unwrap();
        assert_eq!(delta.real.zips_sent, 60);
        assert_eq!(delta.sim.zips_sent, 60);
        let err = delta.throughput_rel_err();
        assert!(
            err < THROUGHPUT_REL_TOL,
            "{}: real {:.3} z/s vs sim {:.3} z/s (rel err {:.2} > {THROUGHPUT_REL_TOL})",
            cfg.name,
            delta.real.mean_throughput_rps,
            delta.sim.mean_throughput_rps,
            err,
        );
        // both modes fully drain the offered load into the warehouse
        assert_eq!(delta.real.rows_inserted, delta.sim.rows_inserted);
        assert_eq!(delta.real.stage_errors, 0);
        assert_eq!(delta.sim.stage_errors, 0);
    }
}

#[test]
fn sim_mode_preserves_the_variant_ordering() {
    // whatever the absolute agreement, the sim must rank the variants
    // like the paper does: no-blocking > blocking > cpu-limited
    let harness = ExperimentHarness::new(1000.0);
    let exp = saturating_experiment();
    let mut rates = Vec::new();
    for cfg in VariantConfig::paper_variants() {
        let rec = harness.simulate(&cfg, &exp).unwrap();
        rates.push((cfg.name, rec.mean_throughput_rps));
    }
    assert!(
        rates[1].1 > rates[0].1 && rates[0].1 > rates[2].1,
        "sim ordering wrong: {rates:?}"
    );
}

#[test]
fn sim_mode_is_bit_deterministic_across_runs() {
    let harness = ExperimentHarness::new(1000.0);
    let exp = saturating_experiment();
    let cfg = VariantConfig::blocking_write();
    let a = harness.simulate(&cfg, &exp).unwrap();
    let b = harness.simulate(&cfg, &exp).unwrap();
    assert_eq!(a.duration_s.to_bits(), b.duration_s.to_bits());
    assert_eq!(a.mean_throughput_rps.to_bits(), b.mean_throughput_rps.to_bits());
    assert_eq!(a.latency_e2e_mean_s.to_bits(), b.latency_e2e_mean_s.to_bits());
    assert_eq!(a.rows_inserted, b.rows_inserted);
}

/// Sim-vs-analytic at 2%: the same kernel the experiment simulator runs
/// on, configured to M/M/1 assumptions and held against the closed-form
/// oracle — a seeded, deterministic guard that catches kernel parity
/// regressions 22× tighter than the real-vs-sim band above. Reuses the
/// committed `mm1-fifo` case from the canonical validation suite (seed
/// and horizon verified to land every metric near or below 1%).
#[test]
fn sim_vs_analytic_mm1_within_two_percent() {
    use plantd::validate::suite::{run_case, DES_VS_ANALYTIC_REL_TOL};
    use plantd::validate::ValidationSuite;

    let case = ValidationSuite::queueing()
        .cases
        .into_iter()
        .find(|c| c.name == "mm1-fifo")
        .expect("canonical mm1 case exists");
    assert_eq!(case.tol_rel, DES_VS_ANALYTIC_REL_TOL);
    let result = run_case(&case);
    for c in &result.checks {
        assert!(
            c.pass,
            "mm1-fifo/{}: analytic {} vs measured {} ({} err {:.4} >= {})",
            c.metric, c.analytic, c.measured, c.mode, c.err, c.tol
        );
    }
    // the kernel really ran: Poisson arrivals + completions, all drained
    assert_eq!(result.events as usize, 2 * case.arrivals);
    assert!(result.makespan_s > 0.0);
}

//! Multi-threaded stress tests for the lock-free telemetry plane
//! (PR 10): SPSC span rings, seqlock cost snapshots, and the
//! ring-vs-locked `ExperimentRecord` equivalence claim — a ring-drained
//! real-mode run must produce the same aggregate totals (spans, records,
//! bytes, errors, cost rate) as the legacy mutex-shared sink on the same
//! seed. Every test name starts with `telemetry_` so CI can run the
//! whole file with `cargo test telemetry_`.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use plantd::datagen::{DataSet, DataSetSpec};
use plantd::experiment::{Experiment, ExperimentHarness};
use plantd::loadgen::LoadPattern;
use plantd::pipeline::VariantConfig;
use plantd::telemetry::{ring, RingConsumer, Seqlock};

/// The paper's automotive-telemetry workload at integration-test scale:
/// a ramp of vehicle transmissions with a few percent of bad records.
fn paper_automotive_exp() -> Experiment {
    Experiment::new(
        "paper-automotive",
        LoadPattern::ramp(10.0, 0.0, 8.0), // 40 zips
        DataSet::generate(DataSetSpec {
            payloads: 16,
            records_per_subsystem: 5,
            bad_rate: 0.05,
            seed: 0xCAB5,
        }),
    )
}

#[test]
fn telemetry_ring_no_loss_below_capacity() {
    // N producers x 1 consumer (one SPSC ring per producer, as the
    // harness wires it): staying below capacity, every value arrives
    const PRODUCERS: usize = 8;
    const PER_PRODUCER: u64 = 50_000;
    const CAPACITY: usize = 1024;

    let mut producers = Vec::new();
    let mut consumers: Vec<RingConsumer<u64>> = Vec::new();
    for _ in 0..PRODUCERS {
        let (p, c) = ring::<u64>(CAPACITY);
        producers.push(p);
        consumers.push(c);
    }
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        let stop_c = stop.clone();
        let drainer = s.spawn(move || {
            let mut got: Vec<Vec<u64>> = vec![Vec::new(); PRODUCERS];
            loop {
                let mut n = 0;
                for (i, c) in consumers.iter_mut().enumerate() {
                    n += c.drain_into(&mut got[i]);
                }
                if n == 0 {
                    if stop_c.load(Ordering::Acquire) {
                        for (i, c) in consumers.iter_mut().enumerate() {
                            c.drain_into(&mut got[i]);
                        }
                        break;
                    }
                    std::thread::yield_now();
                }
            }
            let dropped: u64 = consumers.iter().map(|c| c.dropped()).sum();
            (got, dropped)
        });
        std::thread::scope(|inner| {
            for mut p in producers.drain(..) {
                inner.spawn(move || {
                    for v in 0..PER_PRODUCER {
                        // below-capacity contract: wait for the consumer
                        // instead of dropping
                        while !p.push(v) {
                            std::thread::yield_now();
                        }
                    }
                });
            }
        });
        stop.store(true, Ordering::Release);
        let (got, dropped) = drainer.join().unwrap();
        for (i, vals) in got.iter().enumerate() {
            assert_eq!(
                vals.len() as u64,
                PER_PRODUCER,
                "producer {i} lost values"
            );
            // publish-order visibility: each ring is FIFO
            for (j, v) in vals.iter().enumerate() {
                assert_eq!(*v, j as u64, "producer {i} reordered at {j}");
            }
        }
        // the retry loop above pushes the same value again after a
        // failed attempt, so every drop is later compensated — but the
        // counter still records each refusal honestly; with 50k values
        // through a 1k ring some backpressure refusals are expected
        let _ = dropped;
    });
}

#[test]
fn telemetry_ring_exact_drop_accounting() {
    // no consumer draining: past capacity every push is refused and
    // counted, and what was accepted survives in publish order
    const CAPACITY: usize = 1024; // already a power of two
    let (mut p, mut c) = ring::<u64>(CAPACITY);
    assert_eq!(p.capacity(), CAPACITY);
    let total = 3 * CAPACITY as u64;
    let mut accepted = 0u64;
    for v in 0..total {
        if p.push(v) {
            accepted += 1;
        }
    }
    assert_eq!(accepted, CAPACITY as u64, "exactly one ring's worth fits");
    assert_eq!(p.dropped(), total - CAPACITY as u64);
    assert_eq!(c.dropped(), total - CAPACITY as u64);
    let mut out = Vec::new();
    c.drain_into(&mut out);
    assert_eq!(out, (0..CAPACITY as u64).collect::<Vec<_>>());
    // after draining, the ring accepts again without forgetting drops
    assert!(p.push(999));
    assert_eq!(p.dropped(), total - CAPACITY as u64);
    assert_eq!(c.pop(), Some(999));
    assert_eq!(c.pop(), None);
}

#[test]
fn telemetry_seqlock_never_tears() {
    // writer storm vs readers: the invariant (b == 2a, c == 3a) can only
    // break if a reader observes a half-updated snapshot
    let cell: Arc<Seqlock<3>> = Arc::new(Seqlock::new());
    cell.write(&[0, 0, 0]);
    let stop = Arc::new(AtomicBool::new(false));

    std::thread::scope(|s| {
        let writer_cell = cell.clone();
        let writer_stop = stop.clone();
        s.spawn(move || {
            let mut k = 1u64;
            while !writer_stop.load(Ordering::Relaxed) {
                writer_cell.write(&[k, 2 * k, 3 * k]);
                k = k.wrapping_add(1);
            }
        });
        let mut readers = Vec::new();
        for _ in 0..3 {
            let cell = cell.clone();
            readers.push(s.spawn(move || {
                let mut last = 0u64;
                for _ in 0..200_000 {
                    let [a, b, c] = cell.read();
                    assert_eq!(b, 2 * a, "torn read: [{a}, {b}, {c}]");
                    assert_eq!(c, 3 * a, "torn read: [{a}, {b}, {c}]");
                    last = last.max(a);
                }
                last
            }));
        }
        let progressed = readers
            .into_iter()
            .map(|r| r.join().unwrap())
            .max()
            .unwrap();
        stop.store(true, Ordering::Relaxed);
        assert!(progressed > 0, "readers never saw a published write");
    });
}

#[test]
fn telemetry_ring_vs_locked_record_equivalence() {
    // the PR 10 pinned claim: a ring-drained run produces the same
    // ExperimentRecord aggregate totals as the locked path on the same
    // seed. Wall-noise-dependent fields (durations, latencies) are
    // excluded; everything counted is compared exactly.
    let exp = paper_automotive_exp();
    let variant = VariantConfig::blocking_write();

    let ring_h = ExperimentHarness::new(600.0);
    let ring_rec = ring_h.run(&variant, &exp).unwrap();
    let locked_h = ExperimentHarness::new(600.0);
    let locked_rec = locked_h.run_locked(&variant, &exp).unwrap();

    assert_eq!(ring_rec.zips_sent, locked_rec.zips_sent);
    assert_eq!(ring_rec.rows_inserted, locked_rec.rows_inserted);
    assert_eq!(ring_rec.rows_scrubbed, locked_rec.rows_scrubbed);
    assert_eq!(ring_rec.stage_errors, locked_rec.stage_errors);
    assert_eq!(ring_rec.cost_per_hr_usd, locked_rec.cost_per_hr_usd);
    assert_eq!(ring_rec.spans_dropped, 0, "rings must not overflow here");
    assert_eq!(locked_rec.spans_dropped, 0, "the locked path never drops");

    assert_eq!(ring_rec.per_stage.len(), locked_rec.per_stage.len());
    for ((rn, rspans, rrecs, _), (ln, lspans, lrecs, _)) in
        ring_rec.per_stage.iter().zip(&locked_rec.per_stage)
    {
        assert_eq!(rn, ln);
        assert_eq!(rspans, lspans, "stage {rn}: span totals diverged");
        assert_eq!(rrecs, lrecs, "stage {rn}: record totals diverged");
    }

    // the TSDB saw identical span-derived totals through both routes
    for metric in ["stage_records", "stage_bytes", "stage_errors"] {
        let ring_total = ring_h.tsdb.sum_range(metric, &[], 0.0, f64::MAX);
        let locked_total = locked_h.tsdb.sum_range(metric, &[], 0.0, f64::MAX);
        assert_eq!(
            ring_total as u64, locked_total as u64,
            "{metric}: ring {ring_total} vs locked {locked_total}"
        );
    }

    // total cost is rate x prorated duration on both paths (duration
    // itself is wall-noise, the identity is not)
    for rec in [&ring_rec, &locked_rec] {
        let expect = rec.cost_per_hr_usd * rec.duration_s / 3600.0;
        assert!((rec.total_cost_usd - expect).abs() < 1e-12);
    }
}

#[test]
fn telemetry_e2e_sample_count_matches_etl_span_count() {
    // satellite of the drained_s+1.0 fix: with the fudge gone, the
    // inclusive [started_s, drained_s] window captures exactly one
    // cum-latency sample per ETL span — no more, no fewer
    let harness = ExperimentHarness::new(600.0);
    let variant = VariantConfig::no_blocking_write();
    let rec = harness.run(&variant, &paper_automotive_exp()).unwrap();
    let e2e = harness.tsdb.values_range(
        "stage_cum_latency_s",
        &[("stage", "etl_phase"), ("pipeline", variant.name)],
        rec.started_s,
        rec.drained_s,
    );
    let etl_spans = rec
        .per_stage
        .iter()
        .find(|(name, ..)| name.as_str() == "etl_phase")
        .map(|(_, spans, ..)| *spans)
        .expect("etl_phase stats present");
    assert!(etl_spans > 0);
    assert_eq!(e2e.len() as u64, etl_spans);
}

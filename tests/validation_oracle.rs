//! The sim kernel vs closed-form ground truth: the canonical queueing
//! conformance suite must pass at its 2% tolerance, byte-identically at
//! any thread count, and be drivable through the declarative resource
//! API (`Validation` kind). Also home of the Lifo-vs-Fifo discipline
//! contrast test (same arrivals, same service draws: identical
//! throughput, strictly different sojourn ordering).

use plantd::resources::controller::Controller;
use plantd::resources::{Kind, Phase, Registry};
use plantd::sim::{derive_seed, Discipline, Served, StationConfig, Tandem};
use plantd::util::json::Json;
use plantd::util::rng::Rng;
use plantd::util::stats;
use plantd::validate::suite::DES_VS_ANALYTIC_REL_TOL;
use plantd::validate::ValidationSuite;

/// The acceptance bar: every DES metric of every canonical case lands
/// within 2% of the closed-form value at the committed horizons, and
/// the report is byte-identical on 1 and 8 threads.
#[test]
fn queueing_suite_passes_at_two_percent_on_one_and_eight_threads() {
    let suite = ValidationSuite::queueing();
    assert!(suite.cases.len() >= 6, "acceptance bar: >= 6 analytic cases");
    let serial = suite.run(1);
    let parallel = suite.run(8);
    assert_eq!(
        serial.to_json().to_string_pretty(),
        parallel.to_json().to_string_pretty(),
        "suite report must be byte-identical at any thread count"
    );
    for r in &parallel.results {
        for c in &r.checks {
            assert!(
                c.pass,
                "{}/{}: analytic {} vs measured {} ({} err {:.4} >= tol {})",
                r.name, c.metric, c.analytic, c.measured, c.mode, c.err, c.tol
            );
            if c.mode == "rel" {
                assert_eq!(c.tol, DES_VS_ANALYTIC_REL_TOL, "{}/{}", r.name, c.metric);
            }
        }
    }
    assert!(parallel.pass());
}

/// Run the suite through the PR-3 controller: a `Validation` resource
/// declared in a manifest reconciles, executes, and records its verdict
/// in the resource status.
#[test]
fn validation_resource_runs_through_the_controller() {
    let c = Controller::new(Registry::new());
    c.apply_manifest(
        &Json::parse(
            r#"{"resources": [{"kind": "Validation", "name": "queueing",
                "spec": {"suite": "queueing", "threads": 8}}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    c.reconcile();
    assert_eq!(
        c.registry().get(Kind::Validation, "queueing").unwrap().phase,
        Phase::Ready
    );
    let outcome = c.run(Kind::Validation, "queueing").unwrap();
    assert_eq!(outcome.phase, Phase::Completed);
    assert!(outcome.output.contains("VALIDATION 'queueing'"));
    assert!(outcome.output.contains("all PASS"));
    let res = c.registry().get(Kind::Validation, "queueing").unwrap();
    assert_eq!(res.status.get_str("suite"), Some("queueing"));
    assert_eq!(res.status.get_u64("targets"), Some(6));
    assert_eq!(
        res.status
            .get("failed")
            .and_then(Json::as_arr)
            .map(|a| a.len()),
        Some(0)
    );
    let queueing = res.status.get("queueing").unwrap();
    assert_eq!(queueing.get("pass"), Some(&Json::Bool(true)));
}

/// A bad suite name is a validation (spec) failure, caught at reconcile
/// time — before anything executes.
#[test]
fn unknown_suite_fails_reconciliation() {
    let c = Controller::new(Registry::new());
    c.apply_manifest(
        &Json::parse(
            r#"{"resources": [{"kind": "Validation", "name": "bad",
                "spec": {"suite": "vibes"}}]}"#,
        )
        .unwrap(),
    )
    .unwrap();
    c.reconcile();
    let res = c.registry().get(Kind::Validation, "bad").unwrap();
    assert_eq!(res.phase, Phase::Failed);
    assert!(res.conditions.last().unwrap().contains("vibes"));
}

/// Same arrivals, same per-job service draws, only the discipline
/// differs: throughput (served count, drain time, busy time) must be
/// identical — both disciplines are work-conserving — while the
/// sojourn-time *ordering* must differ strictly, with the Lifo tail at
/// or above the Fifo tail under backlog.
#[test]
fn lifo_vs_fifo_same_throughput_different_sojourn_ordering() {
    let n = 60_000usize;
    let seed = 0x11AD_F1F0u64;
    let (lambda, mu) = (0.9, 1.0); // ρ = 0.9: deep backlogs, fat Lifo tail
    let mut arr_rng = Rng::new(derive_seed(seed, [1, 0, 0]));
    let mut arrivals = Vec::with_capacity(n);
    let mut t = 0.0;
    for i in 0..n {
        t += arr_rng.exponential(lambda);
        arrivals.push((t, i));
    }
    let mut svc_rng = Rng::new(derive_seed(seed, [2, 0, 0]));
    let service: Vec<f64> = (0..n).map(|_| svc_rng.exponential(mu)).collect();

    let run = |discipline: Discipline| {
        let tandem = Tandem::new(vec![
            StationConfig::single("s").with_discipline(discipline)
        ]);
        let out = tandem.run(arrivals.clone(), |_, _, jobs: &mut Vec<usize>| Served {
            service_s: service[jobs[0]],
            next: jobs.clone(),
        });
        let sojourns: Vec<f64> = out
            .completions
            .iter()
            .map(|(tc, idx)| tc - arrivals[*idx].0)
            .collect();
        (out, sojourns)
    };
    let (fifo_out, fifo_sojourns) = run(Discipline::Fifo);
    let (lifo_out, lifo_sojourns) = run(Discipline::Lifo);

    // identical throughput: same jobs served, same total work, same
    // drain time (equal up to float summation order, which differs
    // between the disciplines — hence ulp-level, not bitwise, equality)
    assert_eq!(fifo_out.stations[0].served, n as u64);
    assert_eq!(lifo_out.stations[0].served, n as u64);
    let busy_rel = (fifo_out.stations[0].busy_s - lifo_out.stations[0].busy_s).abs()
        / fifo_out.stations[0].busy_s;
    assert!(
        busy_rel < 1e-9,
        "work conservation: total service time is discipline-independent (rel {busy_rel})"
    );
    let drain_rel =
        (fifo_out.drained_s() - lifo_out.drained_s()).abs() / fifo_out.drained_s();
    assert!(
        drain_rel < 1e-9,
        "throughput: drain time is discipline-independent (rel {drain_rel})"
    );

    // strictly different sojourn ordering: under backlog Lifo trades a
    // fatter tail for a better median...
    let fifo_p99 = stats::quantile(&fifo_sojourns, 0.99);
    let lifo_p99 = stats::quantile(&lifo_sojourns, 0.99);
    assert!(
        lifo_p99 > fifo_p99,
        "Lifo p99 {lifo_p99} must exceed Fifo p99 {fifo_p99} under backlog"
    );
    let fifo_p50 = stats::quantile(&fifo_sojourns, 0.5);
    let lifo_p50 = stats::quantile(&lifo_sojourns, 0.5);
    assert!(
        lifo_p50 < fifo_p50,
        "Lifo median {lifo_p50} must beat Fifo median {fifo_p50} under backlog"
    );
    // ...while the mean is discipline-independent in expectation
    // (Little's law; with job-attached service draws the finite-horizon
    // realizations differ slightly — observed ~0.6% at this seed)
    let fifo_mean = stats::mean(&fifo_sojourns);
    let lifo_mean = stats::mean(&lifo_sojourns);
    assert!(
        (fifo_mean - lifo_mean).abs() / fifo_mean < 0.05,
        "means diverged: fifo {fifo_mean} vs lifo {lifo_mean}"
    );
}

/// The closed-form JSON (the committed snapshot's source) is invariant
/// under horizon scaling and repeated evaluation.
#[test]
fn closed_form_oracle_is_invariant() {
    let full = ValidationSuite::queueing().closed_form_json();
    let small = ValidationSuite::queueing_sized(0.05).closed_form_json();
    assert_eq!(full.to_string_pretty(), small.to_string_pretty());
}

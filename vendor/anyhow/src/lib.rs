//! Minimal offline stand-in for the `anyhow` crate.
//!
//! PlantD builds in a hermetic environment with no crates.io access, so the
//! workspace vendors the tiny subset of `anyhow`'s API the codebase uses:
//! [`Error`], [`Result`], [`anyhow!`], [`bail!`], [`ensure!`], and the
//! [`Context`] extension trait. Semantics match upstream where it matters:
//!
//! - `Error` is a cheap opaque error that displays its message; the
//!   alternate format (`{:#}`) appends the context/source chain, most
//!   recent context first.
//! - `?` converts any `std::error::Error + Send + Sync + 'static` into an
//!   `Error` (and `Error` deliberately does *not* implement
//!   `std::error::Error`, exactly like upstream, so the blanket `From`
//!   impl stays coherent).

use std::fmt;

/// `Result<T, anyhow::Error>` — the crate's ubiquitous alias.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// An opaque error: a message plus a chain of context strings.
pub struct Error {
    /// Most recent context first; the root cause is last.
    chain: Vec<String>,
}

impl Error {
    /// Create an error from a displayable message (mirrors
    /// `anyhow::Error::msg`).
    pub fn msg<M: fmt::Display>(msg: M) -> Error {
        Error {
            chain: vec![msg.to_string()],
        }
    }

    /// Prepend a context message (what [`Context`] does under the hood).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost (most recent) message.
    pub fn root_message(&self) -> &str {
        self.chain.first().map(String::as_str).unwrap_or("")
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}` — the full chain, like anyhow's alternate format
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.root_message())
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // anyhow's Debug prints the message plus a cause list; keep the
        // same shape so `fn main() -> anyhow::Result<()>` failures read well
        write!(f, "{}", self.root_message())?;
        if self.chain.len() > 1 {
            write!(f, "\n\nCaused by:")?;
            for cause in &self.chain[1..] {
                write!(f, "\n    {cause}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(err: E) -> Error {
        let mut chain = vec![err.to_string()];
        let mut src = err.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// Extension trait adding `.context(..)` / `.with_context(..)` to `Result`
/// and `Option` (the subset of `anyhow::Context` PlantD uses).
pub trait Context<T> {
    /// Wrap the error with a fixed context message.
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    /// Wrap the error with a lazily-built context message.
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: Into<Error>,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(context)
        })
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| {
            let err: Error = e.into();
            err.context(f())
        })
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
}

/// Return early with an [`Error`] built from a format string.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with an error unless a condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            $crate::bail!("condition failed: {}", stringify!($cond));
        }
    };
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "missing file")
    }

    #[test]
    fn display_and_alternate_chain() {
        let e: Error = Error::from(io_err()).context("reading manifest");
        assert_eq!(format!("{e}"), "reading manifest");
        assert_eq!(format!("{e:#}"), "reading manifest: missing file");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn inner() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(inner().is_err());
    }

    #[test]
    fn macros_build_errors() {
        fn f(x: i32) -> Result<i32> {
            ensure!(x >= 0, "negative input {x}");
            if x > 100 {
                bail!("too big: {x}");
            }
            Ok(x)
        }
        assert_eq!(f(5).unwrap(), 5);
        assert_eq!(format!("{}", f(-1).unwrap_err()), "negative input -1");
        assert_eq!(format!("{}", f(200).unwrap_err()), "too big: 200");
        let e = anyhow!("plain {}", "message");
        assert_eq!(format!("{e}"), "plain message");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("nothing there").unwrap_err();
        assert_eq!(format!("{e}"), "nothing there");
    }
}

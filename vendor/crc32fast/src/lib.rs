//! Minimal offline stand-in for the `crc32fast` crate: the standard
//! CRC-32/ISO-HDLC (IEEE 802.3) checksum used by zip, gzip and PNG —
//! reflected polynomial `0xEDB88320`, initial value `0xFFFFFFFF`, final
//! XOR `0xFFFFFFFF`.
//!
//! A 256-entry lookup table is built once at first use; throughput is
//! ~0.5 GB/s, far from the SIMD upstream but comfortably off PlantD's
//! hot paths (checksums guard the synthetic wire format, not a kernel).

use std::sync::OnceLock;

fn table() -> &'static [u32; 256] {
    static TABLE: OnceLock<[u32; 256]> = OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, entry) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 {
                    0xEDB8_8320 ^ (c >> 1)
                } else {
                    c >> 1
                };
            }
            *entry = c;
        }
        t
    })
}

/// Streaming CRC-32 hasher (subset of the upstream `Hasher` API).
#[derive(Debug, Clone)]
pub struct Hasher {
    state: u32,
}

impl Default for Hasher {
    fn default() -> Self {
        Self::new()
    }
}

impl Hasher {
    /// Start a fresh checksum.
    pub fn new() -> Hasher {
        Hasher { state: 0xFFFF_FFFF }
    }

    /// Feed bytes into the checksum.
    pub fn update(&mut self, data: &[u8]) {
        let t = table();
        let mut c = self.state;
        for &b in data {
            c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
        }
        self.state = c;
    }

    /// Finish and return the checksum.
    pub fn finalize(self) -> u32 {
        self.state ^ 0xFFFF_FFFF
    }
}

/// One-shot CRC-32 of a byte slice (the function PlantD calls).
pub fn hash(data: &[u8]) -> u32 {
    let mut h = Hasher::new();
    h.update(data);
    h.finalize()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // canonical check value for "123456789"
        assert_eq!(hash(b"123456789"), 0xCBF4_3926);
        assert_eq!(hash(b""), 0);
        assert_eq!(hash(b"a"), 0xE8B7_BE43);
        // IEEE 802.3 residue check: appending the (little-endian) CRC
        // makes the running state hit the magic residue
        let mut data = b"The quick brown fox jumps over the lazy dog".to_vec();
        assert_eq!(hash(&data), 0x414F_A339);
        let crc = hash(&data);
        data.extend_from_slice(&crc.to_le_bytes());
        assert_eq!(hash(&data), 0x2144_DF1C);
    }

    #[test]
    fn streaming_matches_oneshot() {
        let data: Vec<u8> = (0..=255).cycle().take(10_000).collect();
        let mut h = Hasher::new();
        for chunk in data.chunks(97) {
            h.update(chunk);
        }
        assert_eq!(h.finalize(), hash(&data));
    }

    #[test]
    fn single_bit_flips_change_checksum() {
        let data = vec![0xA5u8; 512];
        let base = hash(&data);
        for byte in [0usize, 100, 511] {
            for bit in 0..8 {
                let mut d = data.clone();
                d[byte] ^= 1 << bit;
                assert_ne!(hash(&d), base, "flip at {byte}.{bit} undetected");
            }
        }
    }
}

//! DEFLATE (RFC 1951) from scratch: a greedy hash-chain LZ77 compressor
//! emitting one fixed-Huffman block, and a full inflater supporting
//! stored, fixed, and dynamic blocks (the canonical-Huffman decode loop is
//! the classic `puff.c` algorithm).
//!
//! The compressor favours simplicity over ratio — fixed codes only, greedy
//! matching, bounded chain search — which is plenty for PlantD's synthetic
//! telematics binaries (repeated VINs and timestamp prefixes deflate to
//! roughly half their raw size). The inflater is standard-conformant so
//! the container can also open foreign zips.

// ---------------------------------------------------------------------------
// shared tables
// ---------------------------------------------------------------------------

/// Base match length for length codes 257..=285.
const LENGTH_BASE: [u16; 29] = [
    3, 4, 5, 6, 7, 8, 9, 10, 11, 13, 15, 17, 19, 23, 27, 31, 35, 43, 51, 59,
    67, 83, 99, 115, 131, 163, 195, 227, 258,
];
/// Extra bits for length codes 257..=285.
const LENGTH_EXTRA: [u8; 29] = [
    0, 0, 0, 0, 0, 0, 0, 0, 1, 1, 1, 1, 2, 2, 2, 2, 3, 3, 3, 3, 4, 4, 4, 4,
    5, 5, 5, 5, 0,
];
/// Base distance for distance codes 0..=29.
const DIST_BASE: [u16; 30] = [
    1, 2, 3, 4, 5, 7, 9, 13, 17, 25, 33, 49, 65, 97, 129, 193, 257, 385, 513,
    769, 1025, 1537, 2049, 3073, 4097, 6145, 8193, 12289, 16385, 24577,
];
/// Extra bits for distance codes 0..=29.
const DIST_EXTRA: [u8; 30] = [
    0, 0, 0, 0, 1, 1, 2, 2, 3, 3, 4, 4, 5, 5, 6, 6, 7, 7, 8, 8, 9, 9, 10, 10,
    11, 11, 12, 12, 13, 13,
];

const MIN_MATCH: usize = 3;
const MAX_MATCH: usize = 258;
const WINDOW: usize = 32 * 1024;

/// Decompression error.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct InflateError(pub &'static str);

impl std::fmt::Display for InflateError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "inflate: {}", self.0)
    }
}

impl std::error::Error for InflateError {}

// ---------------------------------------------------------------------------
// bit I/O
// ---------------------------------------------------------------------------

/// LSB-first bit writer (DEFLATE's bit order).
struct BitWriter {
    out: Vec<u8>,
    bitbuf: u32,
    nbits: u32,
}

impl BitWriter {
    fn new() -> Self {
        BitWriter {
            out: Vec::new(),
            bitbuf: 0,
            nbits: 0,
        }
    }

    /// Append `n` bits of `value`, least significant bit first.
    fn write_bits(&mut self, value: u32, n: u32) {
        debug_assert!(n <= 16 && (n == 32 || value < (1 << n)));
        self.bitbuf |= value << self.nbits;
        self.nbits += n;
        while self.nbits >= 8 {
            self.out.push((self.bitbuf & 0xFF) as u8);
            self.bitbuf >>= 8;
            self.nbits -= 8;
        }
    }

    /// Append a Huffman code: `n` bits, most significant code bit first.
    fn write_code(&mut self, code: u32, n: u32) {
        // reverse the low n bits, then emit LSB-first
        let mut rev = 0u32;
        for i in 0..n {
            rev |= ((code >> i) & 1) << (n - 1 - i);
        }
        self.write_bits(rev, n);
    }

    fn finish(mut self) -> Vec<u8> {
        if self.nbits > 0 {
            self.out.push((self.bitbuf & 0xFF) as u8);
        }
        self.out
    }
}

/// LSB-first bit reader.
struct BitReader<'a> {
    data: &'a [u8],
    pos: usize,  // next byte index
    bitbuf: u32, // buffered bits, LSB = next bit
    nbits: u32,
}

impl<'a> BitReader<'a> {
    fn new(data: &'a [u8]) -> Self {
        BitReader {
            data,
            pos: 0,
            bitbuf: 0,
            nbits: 0,
        }
    }

    fn read_bits(&mut self, n: u32) -> Result<u32, InflateError> {
        while self.nbits < n {
            let byte = *self
                .data
                .get(self.pos)
                .ok_or(InflateError("unexpected end of stream"))?;
            self.pos += 1;
            self.bitbuf |= (byte as u32) << self.nbits;
            self.nbits += 8;
        }
        let mask = if n == 0 { 0 } else { (1u32 << n) - 1 };
        let v = self.bitbuf & mask;
        self.bitbuf >>= n;
        self.nbits -= n;
        Ok(v)
    }

    /// Discard buffered bits to realign on a byte boundary (stored blocks).
    fn align_byte(&mut self) {
        self.bitbuf = 0;
        self.nbits = 0;
    }

    fn read_u16_le(&mut self) -> Result<u16, InflateError> {
        if self.pos + 2 > self.data.len() {
            return Err(InflateError("truncated stored block header"));
        }
        let v = u16::from_le_bytes([self.data[self.pos], self.data[self.pos + 1]]);
        self.pos += 2;
        Ok(v)
    }
}

// ---------------------------------------------------------------------------
// canonical Huffman decoding (the puff.c algorithm)
// ---------------------------------------------------------------------------

const MAX_BITS: usize = 15;

struct Huffman {
    /// `counts[l]` = number of symbols with code length `l`.
    counts: [u16; MAX_BITS + 1],
    /// Symbols sorted by (code length, symbol value).
    symbols: Vec<u16>,
}

impl Huffman {
    fn new(lengths: &[u8]) -> Result<Huffman, InflateError> {
        let mut counts = [0u16; MAX_BITS + 1];
        for &l in lengths {
            if l as usize > MAX_BITS {
                return Err(InflateError("code length > 15"));
            }
            counts[l as usize] += 1;
        }
        if counts[0] as usize == lengths.len() {
            return Err(InflateError("no codes in alphabet"));
        }
        // check the code space is not over-subscribed
        let mut left = 1i32;
        for l in 1..=MAX_BITS {
            left <<= 1;
            left -= counts[l] as i32;
            if left < 0 {
                return Err(InflateError("over-subscribed code"));
            }
        }
        // offsets of first symbol of each length in the sorted table
        let mut offs = [0u16; MAX_BITS + 2];
        for l in 1..=MAX_BITS {
            offs[l + 1] = offs[l] + counts[l];
        }
        let mut symbols = vec![0u16; lengths.len()];
        for (sym, &l) in lengths.iter().enumerate() {
            if l != 0 {
                symbols[offs[l as usize] as usize] = sym as u16;
                offs[l as usize] += 1;
            }
        }
        Ok(Huffman { counts, symbols })
    }

    fn decode(&self, br: &mut BitReader) -> Result<u16, InflateError> {
        let mut code = 0i32;
        let mut first = 0i32;
        let mut index = 0i32;
        for len in 1..=MAX_BITS {
            code |= br.read_bits(1)? as i32;
            let count = self.counts[len] as i32;
            if code - first < count {
                return Ok(self.symbols[(index + (code - first)) as usize]);
            }
            index += count;
            first = (first + count) << 1;
            code <<= 1;
        }
        Err(InflateError("invalid Huffman code"))
    }
}

fn fixed_litlen_lengths() -> Vec<u8> {
    let mut l = vec![0u8; 288];
    l[0..144].fill(8);
    l[144..256].fill(9);
    l[256..280].fill(7);
    l[280..288].fill(8);
    l
}

fn fixed_dist_lengths() -> Vec<u8> {
    vec![5u8; 30]
}

// ---------------------------------------------------------------------------
// inflate
// ---------------------------------------------------------------------------

/// Decompress a raw DEFLATE stream.
pub fn inflate(data: &[u8]) -> Result<Vec<u8>, InflateError> {
    let mut br = BitReader::new(data);
    let mut out = Vec::with_capacity(data.len() * 2);
    loop {
        let bfinal = br.read_bits(1)?;
        let btype = br.read_bits(2)?;
        match btype {
            0 => {
                // stored
                br.align_byte();
                let len = br.read_u16_le()?;
                let nlen = br.read_u16_le()?;
                if len != !nlen {
                    return Err(InflateError("stored block LEN/NLEN mismatch"));
                }
                let end = br.pos + len as usize;
                if end > br.data.len() {
                    return Err(InflateError("stored block truncated"));
                }
                out.extend_from_slice(&br.data[br.pos..end]);
                br.pos = end;
            }
            1 => {
                let lit = Huffman::new(&fixed_litlen_lengths())?;
                let dist = Huffman::new(&fixed_dist_lengths())?;
                inflate_block(&mut br, &lit, Some(&dist), &mut out)?;
            }
            2 => {
                let (lit, dist) = read_dynamic_tables(&mut br)?;
                inflate_block(&mut br, &lit, dist.as_ref(), &mut out)?;
            }
            _ => return Err(InflateError("reserved block type")),
        }
        if bfinal == 1 {
            return Ok(out);
        }
    }
}

/// `dist` is `None` for a literal-only dynamic block (RFC 1951 §3.2.7:
/// one distance code of zero bits means no distance codes are used).
fn inflate_block(
    br: &mut BitReader,
    lit: &Huffman,
    dist: Option<&Huffman>,
    out: &mut Vec<u8>,
) -> Result<(), InflateError> {
    loop {
        let sym = lit.decode(br)?;
        match sym {
            0..=255 => out.push(sym as u8),
            256 => return Ok(()),
            257..=285 => {
                let idx = (sym - 257) as usize;
                let len = LENGTH_BASE[idx] as usize
                    + br.read_bits(LENGTH_EXTRA[idx] as u32)? as usize;
                let dist =
                    dist.ok_or(InflateError("length code in literal-only block"))?;
                let dsym = dist.decode(br)? as usize;
                if dsym >= 30 {
                    return Err(InflateError("invalid distance code"));
                }
                let d = DIST_BASE[dsym] as usize
                    + br.read_bits(DIST_EXTRA[dsym] as u32)? as usize;
                if d == 0 || d > out.len() {
                    return Err(InflateError("distance too far back"));
                }
                let start = out.len() - d;
                // overlapping copy must proceed byte-by-byte
                for k in 0..len {
                    let b = out[start + k];
                    out.push(b);
                }
            }
            _ => return Err(InflateError("invalid literal/length symbol")),
        }
    }
}

/// Order in which code-length-code lengths are transmitted.
const CLC_ORDER: [usize; 19] = [
    16, 17, 18, 0, 8, 7, 9, 6, 10, 5, 11, 4, 12, 3, 13, 2, 14, 1, 15,
];

fn read_dynamic_tables(
    br: &mut BitReader,
) -> Result<(Huffman, Option<Huffman>), InflateError> {
    let hlit = br.read_bits(5)? as usize + 257;
    let hdist = br.read_bits(5)? as usize + 1;
    let hclen = br.read_bits(4)? as usize + 4;
    if hlit > 286 || hdist > 30 {
        return Err(InflateError("bad dynamic header counts"));
    }
    let mut clc_lengths = [0u8; 19];
    for &ord in CLC_ORDER.iter().take(hclen) {
        clc_lengths[ord] = br.read_bits(3)? as u8;
    }
    let clc = Huffman::new(&clc_lengths)?;
    let mut lengths = vec![0u8; hlit + hdist];
    let mut i = 0;
    while i < lengths.len() {
        let sym = clc.decode(br)?;
        match sym {
            0..=15 => {
                lengths[i] = sym as u8;
                i += 1;
            }
            16 => {
                if i == 0 {
                    return Err(InflateError("repeat with no previous length"));
                }
                let prev = lengths[i - 1];
                let n = 3 + br.read_bits(2)? as usize;
                if i + n > lengths.len() {
                    return Err(InflateError("repeat overflows alphabet"));
                }
                lengths[i..i + n].fill(prev);
                i += n;
            }
            17 => {
                let n = 3 + br.read_bits(3)? as usize;
                if i + n > lengths.len() {
                    return Err(InflateError("zero-run overflows alphabet"));
                }
                i += n;
            }
            18 => {
                let n = 11 + br.read_bits(7)? as usize;
                if i + n > lengths.len() {
                    return Err(InflateError("zero-run overflows alphabet"));
                }
                i += n;
            }
            _ => return Err(InflateError("bad code-length symbol")),
        }
    }
    let lit = Huffman::new(&lengths[..hlit])?;
    // RFC 1951 §3.2.7: a single zero-length distance code means the block
    // is all literals — valid, and must not be rejected
    let dist = if lengths[hlit..].iter().all(|&l| l == 0) {
        None
    } else {
        Some(Huffman::new(&lengths[hlit..])?)
    };
    Ok((lit, dist))
}

// ---------------------------------------------------------------------------
// deflate
// ---------------------------------------------------------------------------

/// Write the fixed-Huffman code for one literal/length symbol.
fn write_litlen(bw: &mut BitWriter, sym: u16) {
    let s = sym as u32;
    match s {
        0..=143 => bw.write_code(0x30 + s, 8),
        144..=255 => bw.write_code(0x190 + (s - 144), 9),
        256..=279 => bw.write_code(s - 256, 7),
        _ => bw.write_code(0xC0 + (s - 280), 8),
    }
}

/// Largest index `i` such that `table[i] <= v`.
fn bucket_of(table: &[u16], v: usize) -> usize {
    match table.binary_search(&(v as u16)) {
        Ok(i) => i,
        Err(ins) => ins - 1,
    }
}

fn emit_match(bw: &mut BitWriter, len: usize, dist: usize) {
    let li = bucket_of(&LENGTH_BASE, len);
    write_litlen(bw, 257 + li as u16);
    bw.write_bits((len - LENGTH_BASE[li] as usize) as u32, LENGTH_EXTRA[li] as u32);
    let di = bucket_of(&DIST_BASE, dist);
    bw.write_code(di as u32, 5);
    bw.write_bits((dist - DIST_BASE[di] as usize) as u32, DIST_EXTRA[di] as u32);
}

const HASH_BITS: usize = 15;
const HASH_SIZE: usize = 1 << HASH_BITS;
/// How many hash-chain candidates to examine per position.
const MAX_CHAIN: usize = 32;

fn hash3(data: &[u8], i: usize) -> usize {
    let v = (data[i] as u32) | ((data[i + 1] as u32) << 8) | ((data[i + 2] as u32) << 16);
    (v.wrapping_mul(0x9E37_79B1) >> (32 - HASH_BITS)) as usize
}

/// Compress `data` into a single fixed-Huffman DEFLATE block.
pub fn deflate(data: &[u8]) -> Vec<u8> {
    let mut bw = BitWriter::new();
    bw.write_bits(1, 1); // BFINAL
    bw.write_bits(1, 2); // BTYPE = 01 (fixed Huffman)

    let n = data.len();
    // hash chains: head[h] = most recent position with hash h;
    // prev[i & (WINDOW-1)] = previous position with the same hash as i
    let mut head = vec![usize::MAX; HASH_SIZE];
    let mut prev = vec![usize::MAX; WINDOW];

    let insert = |head: &mut [usize], prev: &mut [usize], data: &[u8], i: usize| {
        if i + MIN_MATCH <= data.len() {
            let h = hash3(data, i);
            prev[i & (WINDOW - 1)] = head[h];
            head[h] = i;
        }
    };

    let mut i = 0;
    while i < n {
        let mut best_len = 0usize;
        let mut best_dist = 0usize;
        if i + MIN_MATCH <= n {
            let max_len = MAX_MATCH.min(n - i);
            let mut cand = head[hash3(data, i)];
            let mut chain = 0;
            while cand != usize::MAX && chain < MAX_CHAIN {
                let dist = i - cand;
                if dist > WINDOW {
                    break;
                }
                // candidate positions can alias after WINDOW wraps; verify
                // the first bytes actually match before extending
                let mut l = 0usize;
                while l < max_len && data[cand + l] == data[i + l] {
                    l += 1;
                }
                if l > best_len {
                    best_len = l;
                    best_dist = dist;
                    if l >= max_len {
                        break;
                    }
                }
                let next = prev[cand & (WINDOW - 1)];
                // chains only go backwards; a stale slot would loop forever
                if next >= cand {
                    break;
                }
                cand = next;
                chain += 1;
            }
        }
        if best_len >= MIN_MATCH {
            emit_match(&mut bw, best_len, best_dist);
            // index every position covered by the match
            for k in 0..best_len {
                insert(&mut head, &mut prev, data, i + k);
            }
            i += best_len;
        } else {
            write_litlen(&mut bw, data[i] as u16);
            insert(&mut head, &mut prev, data, i);
            i += 1;
        }
    }
    write_litlen(&mut bw, 256); // end of block
    bw.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn roundtrip(data: &[u8]) {
        let packed = deflate(data);
        let back = inflate(&packed).expect("inflate");
        assert_eq!(back, data, "roundtrip failed for {} bytes", data.len());
    }

    #[test]
    fn roundtrip_empty_and_tiny() {
        roundtrip(b"");
        roundtrip(b"a");
        roundtrip(b"ab");
        roundtrip(b"abc");
    }

    #[test]
    fn roundtrip_all_byte_values() {
        let data: Vec<u8> = (0u8..=255).collect();
        roundtrip(&data);
    }

    #[test]
    fn roundtrip_long_repeat_compresses() {
        let data = vec![0x42u8; 10_000];
        let packed = deflate(&data);
        assert!(packed.len() < 200, "10k run packed to {} bytes", packed.len());
        assert_eq!(inflate(&packed).unwrap(), data);
    }

    #[test]
    fn roundtrip_repeating_structure_compresses() {
        // telemetry-shaped: a 37-byte record with a constant 17-byte VIN
        let mut data = Vec::new();
        for rec in 0u64..500 {
            data.extend_from_slice(&(rec * 100).to_le_bytes());
            data.extend_from_slice(b"1HGCM82633A004352");
            data.extend_from_slice(&(rec as f32).to_le_bytes());
            data.extend_from_slice(&(rec as f32 * 0.5).to_le_bytes());
            data.extend_from_slice(&(rec as f32 * 2.0).to_le_bytes());
        }
        let packed = deflate(&data);
        assert!(
            packed.len() < data.len() * 3 / 4,
            "only {} -> {}",
            data.len(),
            packed.len()
        );
        assert_eq!(inflate(&packed).unwrap(), data);
    }

    #[test]
    fn roundtrip_pseudorandom_data() {
        // xorshift noise: essentially incompressible, exercises the
        // literal path and 9-bit codes
        let mut x = 0x12345678u32;
        let data: Vec<u8> = (0..50_000)
            .map(|_| {
                x ^= x << 13;
                x ^= x >> 17;
                x ^= x << 5;
                (x & 0xFF) as u8
            })
            .collect();
        roundtrip(&data);
    }

    #[test]
    fn roundtrip_overlapping_matches() {
        // "aaa..." forces dist=1 overlapping copies
        roundtrip(&vec![b'a'; 1000]);
        // period-3 pattern
        let data: Vec<u8> = std::iter::repeat(*b"xyz")
            .take(700)
            .flatten()
            .collect();
        roundtrip(&data);
    }

    #[test]
    fn roundtrip_max_match_lengths() {
        // exactly 258 + a boundary, then 259
        for n in [258usize, 259, 260, 516, 517] {
            let mut data = b"HEADER".to_vec();
            data.extend(std::iter::repeat(b'z').take(n));
            data.extend_from_slice(b"TRAILER");
            roundtrip(&data);
        }
    }

    #[test]
    fn roundtrip_larger_than_window() {
        // > 32 KiB with long-range repetition: matches must respect the
        // 32 KiB distance limit
        let unit: Vec<u8> = (0..=255u8).collect();
        let mut data = Vec::new();
        for i in 0..300 {
            data.extend_from_slice(&unit);
            data.push((i % 251) as u8);
        }
        assert!(data.len() > 64 * 1024);
        roundtrip(&data);
    }

    #[test]
    fn inflate_stored_block() {
        // hand-built stored block: BFINAL=1, BTYPE=00
        let payload = b"hello stored";
        let mut raw = vec![0b0000_0001u8]; // final, stored, then align
        raw.extend_from_slice(&(payload.len() as u16).to_le_bytes());
        raw.extend_from_slice(&(!(payload.len() as u16)).to_le_bytes());
        raw.extend_from_slice(payload);
        assert_eq!(inflate(&raw).unwrap(), payload);
    }

    #[test]
    fn inflate_rejects_garbage() {
        assert!(inflate(&[]).is_err());
        assert!(inflate(&[0x07, 0xFF, 0xFF]).is_err()); // reserved BTYPE=11
        // truncated fixed block (no EOB)
        let mut bw = BitWriter::new();
        bw.write_bits(1, 1);
        bw.write_bits(1, 2);
        let bytes = bw.finish();
        assert!(inflate(&bytes).is_err());
    }

    #[test]
    fn inflate_rejects_too_far_distance() {
        // fixed block: literal 'a', then a match with dist 4 (> output)
        let mut bw = BitWriter::new();
        bw.write_bits(1, 1);
        bw.write_bits(1, 2);
        write_litlen(&mut bw, b'a' as u16);
        emit_match(&mut bw, 3, 4);
        write_litlen(&mut bw, 256);
        assert_eq!(
            inflate(&bw.finish()).unwrap_err(),
            InflateError("distance too far back")
        );
    }

    #[test]
    fn bitwriter_bitreader_agree() {
        let mut bw = BitWriter::new();
        bw.write_bits(0b101, 3);
        bw.write_bits(0xBEEF & 0x3FFF, 14);
        bw.write_bits(0, 0);
        bw.write_bits(1, 1);
        let bytes = bw.finish();
        let mut br = BitReader::new(&bytes);
        assert_eq!(br.read_bits(3).unwrap(), 0b101);
        assert_eq!(br.read_bits(14).unwrap(), 0xBEEF & 0x3FFF);
        assert_eq!(br.read_bits(0).unwrap(), 0);
        assert_eq!(br.read_bits(1).unwrap(), 1);
    }

    #[test]
    fn huffman_rejects_oversubscribed() {
        // three 1-bit codes cannot exist
        assert!(Huffman::new(&[1, 1, 1]).is_err());
        assert!(Huffman::new(&[0, 0, 0]).is_err());
        assert!(Huffman::new(&[1, 1]).is_ok());
    }
}

//! Minimal offline stand-in for the `zip` crate.
//!
//! PlantD's wire format is "one zip per vehicle transmission" and its
//! unzipper stage performs real inflation, so this crate implements the
//! subset of the zip container format the codebase needs — local file
//! headers, a central directory, CRC-32 validation — on top of an
//! in-house DEFLATE ([`flate`]). API names mirror the upstream `zip`
//! crate (`ZipWriter`, `ZipArchive`, `write::FileOptions`,
//! `CompressionMethod`) so call sites read identically.

pub mod flate;

use std::fmt;
use std::io::{Read, Write};

const LOCAL_SIG: u32 = 0x0403_4B50;
const CENTRAL_SIG: u32 = 0x0201_4B50;
const EOCD_SIG: u32 = 0x0605_4B50;

/// Errors from reading or writing archives.
#[derive(Debug)]
pub enum ZipError {
    /// Container structure is malformed (bad signature, truncated, …).
    InvalidArchive(&'static str),
    /// An entry's compressed payload failed to inflate or checksum.
    InvalidData(&'static str),
    /// Entry index out of range.
    FileNotFound,
    /// Underlying I/O failure.
    Io(std::io::Error),
}

impl fmt::Display for ZipError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            ZipError::InvalidArchive(m) => write!(f, "invalid zip archive: {m}"),
            ZipError::InvalidData(m) => write!(f, "invalid zip entry data: {m}"),
            ZipError::FileNotFound => write!(f, "zip entry index out of range"),
            ZipError::Io(e) => write!(f, "zip io error: {e}"),
        }
    }
}

impl std::error::Error for ZipError {}

impl From<std::io::Error> for ZipError {
    fn from(e: std::io::Error) -> Self {
        ZipError::Io(e)
    }
}

/// Supported compression methods.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CompressionMethod {
    /// No compression (method 0).
    Stored,
    /// DEFLATE (method 8).
    Deflated,
}

impl CompressionMethod {
    fn code(self) -> u16 {
        match self {
            CompressionMethod::Stored => 0,
            CompressionMethod::Deflated => 8,
        }
    }
}

/// Entry options, mirroring `zip::write::FileOptions`.
pub mod write {
    use super::CompressionMethod;

    /// Per-entry settings for [`super::ZipWriter::start_file`].
    #[derive(Debug, Clone, Copy)]
    pub struct FileOptions {
        pub(crate) method: CompressionMethod,
    }

    impl Default for FileOptions {
        fn default() -> Self {
            FileOptions {
                method: CompressionMethod::Deflated,
            }
        }
    }

    impl FileOptions {
        /// Choose the compression method.
        pub fn compression_method(mut self, method: CompressionMethod) -> Self {
            self.method = method;
            self
        }

        /// Accepted for API compatibility; the vendored DEFLATE has a
        /// single (fast) level.
        pub fn compression_level(self, _level: Option<i32>) -> Self {
            self
        }
    }
}

struct CentralRecord {
    name: String,
    method: u16,
    crc32: u32,
    compressed_size: u32,
    uncompressed_size: u32,
    local_offset: u32,
}

struct PendingEntry {
    name: String,
    method: CompressionMethod,
    data: Vec<u8>,
}

/// Streaming archive writer: `start_file`, `Write` the contents, repeat,
/// then `finish`.
pub struct ZipWriter<W: Write> {
    inner: W,
    offset: u64,
    records: Vec<CentralRecord>,
    current: Option<PendingEntry>,
}

fn push_u16(out: &mut Vec<u8>, v: u16) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn push_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

impl<W: Write> ZipWriter<W> {
    /// Wrap a byte sink.
    pub fn new(inner: W) -> Self {
        ZipWriter {
            inner,
            offset: 0,
            records: Vec::new(),
            current: None,
        }
    }

    /// Begin a new entry; subsequent `write` calls append to it.
    pub fn start_file<S: Into<String>>(
        &mut self,
        name: S,
        options: write::FileOptions,
    ) -> Result<(), ZipError> {
        self.flush_entry()?;
        self.current = Some(PendingEntry {
            name: name.into(),
            method: options.method,
            data: Vec::new(),
        });
        Ok(())
    }

    fn flush_entry(&mut self) -> Result<(), ZipError> {
        let Some(entry) = self.current.take() else {
            return Ok(());
        };
        let crc = crc32fast::hash(&entry.data);
        let compressed = match entry.method {
            CompressionMethod::Stored => entry.data.clone(),
            CompressionMethod::Deflated => flate::deflate(&entry.data),
        };
        let name_bytes = entry.name.as_bytes();
        let mut header = Vec::with_capacity(30 + name_bytes.len());
        push_u32(&mut header, LOCAL_SIG);
        push_u16(&mut header, 20); // version needed
        push_u16(&mut header, 0); // flags
        push_u16(&mut header, entry.method.code());
        push_u16(&mut header, 0); // mod time
        push_u16(&mut header, 0x21); // mod date (1980-01-01)
        push_u32(&mut header, crc);
        push_u32(&mut header, compressed.len() as u32);
        push_u32(&mut header, entry.data.len() as u32);
        push_u16(&mut header, name_bytes.len() as u16);
        push_u16(&mut header, 0); // extra len
        header.extend_from_slice(name_bytes);
        self.inner.write_all(&header)?;
        self.inner.write_all(&compressed)?;
        self.records.push(CentralRecord {
            name: entry.name,
            method: entry.method.code(),
            crc32: crc,
            compressed_size: compressed.len() as u32,
            uncompressed_size: entry.data.len() as u32,
            local_offset: self.offset as u32,
        });
        self.offset += (header.len() + compressed.len()) as u64;
        Ok(())
    }

    /// Flush the last entry, append the central directory, and return the
    /// underlying sink.
    pub fn finish(mut self) -> Result<W, ZipError> {
        self.flush_entry()?;
        let cd_offset = self.offset;
        let mut cd = Vec::new();
        for r in &self.records {
            let name_bytes = r.name.as_bytes();
            push_u32(&mut cd, CENTRAL_SIG);
            push_u16(&mut cd, 20); // version made by
            push_u16(&mut cd, 20); // version needed
            push_u16(&mut cd, 0); // flags
            push_u16(&mut cd, r.method);
            push_u16(&mut cd, 0); // mod time
            push_u16(&mut cd, 0x21); // mod date
            push_u32(&mut cd, r.crc32);
            push_u32(&mut cd, r.compressed_size);
            push_u32(&mut cd, r.uncompressed_size);
            push_u16(&mut cd, name_bytes.len() as u16);
            push_u16(&mut cd, 0); // extra len
            push_u16(&mut cd, 0); // comment len
            push_u16(&mut cd, 0); // disk number
            push_u16(&mut cd, 0); // internal attrs
            push_u32(&mut cd, 0); // external attrs
            push_u32(&mut cd, r.local_offset);
            cd.extend_from_slice(name_bytes);
        }
        let mut eocd = Vec::with_capacity(22);
        push_u32(&mut eocd, EOCD_SIG);
        push_u16(&mut eocd, 0); // disk
        push_u16(&mut eocd, 0); // cd start disk
        push_u16(&mut eocd, self.records.len() as u16);
        push_u16(&mut eocd, self.records.len() as u16);
        push_u32(&mut eocd, cd.len() as u32);
        push_u32(&mut eocd, cd_offset as u32);
        push_u16(&mut eocd, 0); // comment len
        self.inner.write_all(&cd)?;
        self.inner.write_all(&eocd)?;
        Ok(self.inner)
    }
}

impl<W: Write> Write for ZipWriter<W> {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        match &mut self.current {
            Some(entry) => {
                entry.data.extend_from_slice(buf);
                Ok(buf.len())
            }
            None => Err(std::io::Error::new(
                std::io::ErrorKind::Other,
                "no entry started (call start_file first)",
            )),
        }
    }

    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

struct EntryMeta {
    name: String,
    method: u16,
    crc32: u32,
    compressed_size: u32,
    uncompressed_size: u32,
    local_offset: u32,
}

/// Archive reader: parses the central directory eagerly, decompresses
/// entries on access.
pub struct ZipArchive {
    bytes: Vec<u8>,
    entries: Vec<EntryMeta>,
}

fn get_u16(b: &[u8], at: usize) -> Result<u16, ZipError> {
    b.get(at..at + 2)
        .map(|s| u16::from_le_bytes([s[0], s[1]]))
        .ok_or(ZipError::InvalidArchive("truncated"))
}

fn get_u32(b: &[u8], at: usize) -> Result<u32, ZipError> {
    b.get(at..at + 4)
        .map(|s| u32::from_le_bytes([s[0], s[1], s[2], s[3]]))
        .ok_or(ZipError::InvalidArchive("truncated"))
}

impl ZipArchive {
    /// Read the full stream and parse its central directory.
    pub fn new<R: Read>(mut reader: R) -> Result<ZipArchive, ZipError> {
        let mut bytes = Vec::new();
        reader.read_to_end(&mut bytes)?;
        // locate the end-of-central-directory record: scan back for the
        // signature (the record is 22 bytes plus an optional comment)
        if bytes.len() < 22 {
            return Err(ZipError::InvalidArchive("too short for EOCD"));
        }
        let mut eocd_at = None;
        let lo = bytes.len().saturating_sub(22 + u16::MAX as usize);
        for at in (lo..=bytes.len() - 22).rev() {
            if get_u32(&bytes, at)? == EOCD_SIG {
                eocd_at = Some(at);
                break;
            }
        }
        let eocd = eocd_at.ok_or(ZipError::InvalidArchive("no EOCD signature"))?;
        let n_entries = get_u16(&bytes, eocd + 10)? as usize;
        let cd_offset = get_u32(&bytes, eocd + 16)? as usize;
        let mut entries = Vec::with_capacity(n_entries);
        let mut at = cd_offset;
        for _ in 0..n_entries {
            if get_u32(&bytes, at)? != CENTRAL_SIG {
                return Err(ZipError::InvalidArchive("bad central directory entry"));
            }
            let method = get_u16(&bytes, at + 10)?;
            let crc32 = get_u32(&bytes, at + 16)?;
            let compressed_size = get_u32(&bytes, at + 20)?;
            let uncompressed_size = get_u32(&bytes, at + 24)?;
            let name_len = get_u16(&bytes, at + 28)? as usize;
            let extra_len = get_u16(&bytes, at + 30)? as usize;
            let comment_len = get_u16(&bytes, at + 32)? as usize;
            let local_offset = get_u32(&bytes, at + 42)?;
            let name_bytes = bytes
                .get(at + 46..at + 46 + name_len)
                .ok_or(ZipError::InvalidArchive("truncated entry name"))?;
            let name = String::from_utf8_lossy(name_bytes).into_owned();
            entries.push(EntryMeta {
                name,
                method,
                crc32,
                compressed_size,
                uncompressed_size,
                local_offset,
            });
            at += 46 + name_len + extra_len + comment_len;
        }
        Ok(ZipArchive { bytes, entries })
    }

    /// Number of entries.
    pub fn len(&self) -> usize {
        self.entries.len()
    }

    /// Whether the archive has no entries.
    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    /// Decompress and checksum entry `i`.
    pub fn by_index(&mut self, i: usize) -> Result<ZipFile, ZipError> {
        let meta = self.entries.get(i).ok_or(ZipError::FileNotFound)?;
        let at = meta.local_offset as usize;
        if get_u32(&self.bytes, at)? != LOCAL_SIG {
            return Err(ZipError::InvalidArchive("bad local header signature"));
        }
        // the local header's own name/extra lengths govern the data offset
        let name_len = get_u16(&self.bytes, at + 26)? as usize;
        let extra_len = get_u16(&self.bytes, at + 28)? as usize;
        let data_at = at + 30 + name_len + extra_len;
        let compressed = self
            .bytes
            .get(data_at..data_at + meta.compressed_size as usize)
            .ok_or(ZipError::InvalidArchive("truncated entry data"))?;
        let data = match meta.method {
            0 => compressed.to_vec(),
            8 => flate::inflate(compressed)
                .map_err(|e| ZipError::InvalidData(e.0))?,
            _ => return Err(ZipError::InvalidData("unsupported compression method")),
        };
        if data.len() as u32 != meta.uncompressed_size {
            return Err(ZipError::InvalidData("uncompressed size mismatch"));
        }
        if crc32fast::hash(&data) != meta.crc32 {
            return Err(ZipError::InvalidData("crc32 mismatch"));
        }
        Ok(ZipFile {
            name: meta.name.clone(),
            data,
            read_pos: 0,
        })
    }
}

/// One decompressed entry; implements [`Read`] over its contents.
pub struct ZipFile {
    name: String,
    data: Vec<u8>,
    read_pos: usize,
}

impl ZipFile {
    /// Entry name (path inside the archive).
    pub fn name(&self) -> &str {
        &self.name
    }

    /// Uncompressed size in bytes.
    pub fn size(&self) -> u64 {
        self.data.len() as u64
    }
}

impl Read for ZipFile {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = buf.len().min(self.data.len() - self.read_pos);
        buf[..n].copy_from_slice(&self.data[self.read_pos..self.read_pos + n]);
        self.read_pos += n;
        Ok(n)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    fn build(entries: &[(&str, &[u8])], method: CompressionMethod) -> Vec<u8> {
        let mut cursor = Cursor::new(Vec::new());
        {
            let mut zw = ZipWriter::new(&mut cursor);
            let opts = write::FileOptions::default()
                .compression_method(method)
                .compression_level(Some(1));
            for (name, data) in entries {
                zw.start_file(*name, opts).unwrap();
                zw.write_all(data).unwrap();
            }
            zw.finish().unwrap();
        }
        cursor.into_inner()
    }

    fn read_all(bytes: &[u8]) -> Vec<(String, Vec<u8>)> {
        let mut archive = ZipArchive::new(Cursor::new(bytes)).unwrap();
        (0..archive.len())
            .map(|i| {
                let mut f = archive.by_index(i).unwrap();
                let mut buf = Vec::with_capacity(f.size() as usize);
                f.read_to_end(&mut buf).unwrap();
                (f.name().to_string(), buf)
            })
            .collect()
    }

    #[test]
    fn roundtrip_deflated_members() {
        let a = vec![7u8; 4000];
        let b: Vec<u8> = (0..=255u8).cycle().take(1000).collect();
        let zip = build(&[("a.bin", &a), ("dir/b.bin", &b)], CompressionMethod::Deflated);
        let got = read_all(&zip);
        assert_eq!(got.len(), 2);
        assert_eq!(got[0], ("a.bin".to_string(), a.clone()));
        assert_eq!(got[1], ("dir/b.bin".to_string(), b));
        // the repetitive member must actually compress
        assert!(zip.len() < 4000, "archive {} bytes", zip.len());
    }

    #[test]
    fn roundtrip_stored_members() {
        let data = b"store me plainly".to_vec();
        let zip = build(&[("s.txt", &data)], CompressionMethod::Stored);
        assert_eq!(read_all(&zip), vec![("s.txt".to_string(), data)]);
    }

    #[test]
    fn roundtrip_empty_entry_and_empty_archive() {
        let zip = build(&[("empty", b"")], CompressionMethod::Deflated);
        assert_eq!(read_all(&zip), vec![("empty".to_string(), Vec::new())]);
        let none = build(&[], CompressionMethod::Deflated);
        let archive = ZipArchive::new(Cursor::new(&none[..])).unwrap();
        assert!(archive.is_empty());
    }

    #[test]
    fn rejects_garbage() {
        assert!(ZipArchive::new(Cursor::new(b"not a zip" as &[u8])).is_err());
        assert!(ZipArchive::new(Cursor::new(b"" as &[u8])).is_err());
    }

    #[test]
    fn detects_payload_corruption() {
        let data = vec![0x5Au8; 2048];
        let mut zip = build(&[("x", &data)], CompressionMethod::Deflated);
        // flip a byte inside the compressed payload (after the 30+1 byte
        // local header, before the central directory)
        zip[40] ^= 0xFF;
        let mut archive = ZipArchive::new(Cursor::new(&zip[..])).unwrap();
        assert!(archive.by_index(0).is_err());
    }

    #[test]
    fn by_index_out_of_range() {
        let zip = build(&[("x", b"1")], CompressionMethod::Deflated);
        let mut archive = ZipArchive::new(Cursor::new(&zip[..])).unwrap();
        assert!(matches!(archive.by_index(5), Err(ZipError::FileNotFound)));
    }

    #[test]
    fn many_members_order_preserved() {
        let members: Vec<(String, Vec<u8>)> = (0..20)
            .map(|i| (format!("m{i}.bin"), vec![i as u8; 100 + i]))
            .collect();
        let refs: Vec<(&str, &[u8])> = members
            .iter()
            .map(|(n, d)| (n.as_str(), d.as_slice()))
            .collect();
        let zip = build(&refs, CompressionMethod::Deflated);
        assert_eq!(read_all(&zip), members);
    }
}
